//! Pipelined training engine bench: sequential (depth 1) vs overlapped
//! (depth 2) stage schedules over the real pipeline machinery.
//!
//! Runs the trainer's exact stage structure — snapshot-backed sampling
//! through [`PipelineDriver`], a device step, Fig. 1(b) publish through
//! the shared [`ShardSet`] — with the PJRT execute replaced by a
//! calibrated host compute kernel (no artifacts needed; the schedule,
//! sampler, snapshots and publisher are the production code paths). The
//! acceptance shape: at depth 2 the sampling wall time is *hidden* behind
//! the device step (visible `sample_wait` collapses, steps/s rises toward
//! `1 / max(device, sample)` instead of `1 / (device + sample)`), and the
//! publish cost moves off the critical path.
//!
//! Emits `BENCH_train.json` with per-depth steps/s, the per-phase
//! visible/hidden split, and the sequential-vs-pipelined speedup field.
//!
//! `cargo bench --bench train_pipeline` (pure L3).

use kss::bench_harness::{print_speedup, print_table, scale, write_json_value, BenchRow, Scale};
use kss::coordinator::pipeline::{PipelineDriver, SampleTask, SharedPublisher, StepScratch};
use kss::ops;
use kss::sampler::{
    BatchSampleInput, KernelTreeSampler, QuadraticMap, Sample, Sampler, TwoPassKernelSampler,
};
use kss::serve::ShardSet;
use kss::util::json::Value;
use kss::util::rng::Rng;
use std::sync::{Arc, Mutex};
use std::time::Instant;

struct Dims {
    n_classes: usize,
    d: usize,
    rows: usize,
    m: usize,
    steps: usize,
    /// Synthetic device-step cost: repetitions of a 4096-wide dot.
    device_reps: usize,
    threads: usize,
}

struct RunStats {
    wall_s: f64,
    device_s: f64,
    /// Sampling wall on the critical path (all of it at depth 1; only the
    /// collect-blocked remainder at depth 2).
    sample_visible_s: f64,
    /// Sampling wall hidden behind the device step (depth 2 only).
    sample_hidden_s: f64,
    publish_visible_s: f64,
    publish_hidden_s: f64,
}

/// The stand-in for the fused sampled-softmax artifact: a fixed amount of
/// dense host compute (the pipeline only cares that it occupies the
/// coordinator thread for a device-step-like interval).
fn synthetic_device_step(a: &[f32], b: &[f32], reps: usize) -> f32 {
    let mut acc = 0.0f32;
    for _ in 0..reps {
        acc += ops::dot32(std::hint::black_box(a), std::hint::black_box(b));
    }
    acc
}

fn run_depth(depth: usize, dims: &Dims) -> RunStats {
    let Dims { n_classes, d, rows, m, steps, device_reps, threads } = *dims;
    let mut rng = Rng::new(0x7EA1);
    let mut emb = vec![0.0f32; n_classes * d];
    rng.fill_normal(&mut emb, 0.4);
    let set = ShardSet::new(QuadraticMap::new(d, 100.0), n_classes, 1, None, Some(&emb));
    let sampler: Arc<dyn Sampler> = Arc::new(set.snapshot_sampler());
    let publisher: SharedPublisher = Arc::new(Mutex::new(Box::new(set)));
    let mut driver = PipelineDriver::new(depth);
    let mut scratch = StepScratch::default();
    let dev_a: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.001).sin()).collect();
    let dev_b: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.002).cos()).collect();

    let make_task = |t: usize, rows_buf: Vec<kss::sampler::Sample>| {
        // deterministic per-step queries, independent of depth
        let mut hrng = Rng::new(0xBA7C4 ^ t as u64);
        let mut h = vec![0.0f32; rows * d];
        hrng.fill_normal(&mut h, 1.0);
        SampleTask {
            step: t,
            seed: 0x5EED ^ t as u64,
            n: rows,
            d,
            n_classes,
            m,
            threads,
            h: Some(h),
            logits: None,
            prev: None,
            rows: rows_buf,
        }
    };

    let mut stats = RunStats {
        wall_s: 0.0,
        device_s: 0.0,
        sample_visible_s: 0.0,
        sample_hidden_s: 0.0,
        publish_visible_s: 0.0,
        publish_hidden_s: 0.0,
    };
    let mut sink = 0.0f32;
    let t_run = Instant::now();
    for t in 0..steps {
        if driver.in_flight() == 0 {
            let buf = scratch.take_rows(rows, m);
            driver.schedule_sample(&sampler, make_task(t, buf));
        }
        let (outcome, wait_s) = driver.collect_sample();
        outcome.result.as_ref().expect("sampling failed");
        if depth > 1 {
            stats.sample_visible_s += wait_s;
            // only the part that finished before collect was hidden
            stats.sample_hidden_s += (outcome.sample_s - wait_s).max(0.0);
        } else {
            stats.sample_visible_s += outcome.sample_s;
        }
        if t + 1 < steps {
            let buf = scratch.take_rows(rows, m);
            driver.schedule_sample(&sampler, make_task(t + 1, buf));
        }
        // device step occupies the coordinator thread
        let t_dev = Instant::now();
        sink += synthetic_device_step(&dev_a, &dev_b, device_reps);
        stats.device_s += t_dev.elapsed().as_secs_f64();
        // Fig. 1(b): the sampled classes' rows changed — publish them
        // (classes fresh per step, as apply_sampled_rows produces them;
        // the rows payload round-trips through the driver's pool)
        let mut classes: Vec<usize> =
            outcome.rows.iter().flat_map(|r| r.classes.iter().map(|&c| c as usize)).collect();
        classes.sort_unstable();
        classes.dedup();
        let mut urng = Rng::new(0x0DD ^ t as u64);
        let mut rows_flat = driver.take_rows_buf();
        rows_flat.clear();
        rows_flat.resize(classes.len() * d, 0.0);
        urng.fill_normal(&mut rows_flat, 0.4);
        if let Some(secs) = driver.schedule_publish(&publisher, classes, rows_flat, depth > 1) {
            stats.publish_visible_s += secs;
        }
        scratch.put_rows(outcome.rows);
    }
    stats.publish_hidden_s = driver.drain();
    stats.wall_s = t_run.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    let pstats = publisher.lock().unwrap().publish_stats();
    assert_eq!(pstats.publishes as usize, steps, "every step must publish");
    stats
}

/// Raw sampler-stage throughput: batches of `sample_batch` per second.
fn sampler_batches_per_s(
    s: &dyn Sampler,
    hs: &[f32],
    rows: usize,
    d: usize,
    n_classes: usize,
    m: usize,
    threads: usize,
    batches: usize,
) -> f64 {
    let inputs =
        BatchSampleInput { n: rows, d, n_classes, h: Some(hs), threads, ..Default::default() };
    let mut out: Vec<Sample> = (0..rows).map(|_| Sample::with_capacity(m)).collect();
    s.sample_batch(&inputs, m, 0xFACE, &mut out).expect("warmup batch failed");
    let t0 = Instant::now();
    for step in 0..batches {
        s.sample_batch(&inputs, m, 0x100 + step as u64, &mut out).expect("bench batch failed");
    }
    batches as f64 / t0.elapsed().as_secs_f64().max(1e-12)
}

/// The two-pass satellite sweep: per-row tree descent vs the batch-shared
/// pool engine over m ∈ {50, 100, 500} × α ∈ {2, 4, 8}, on the sampling
/// stage alone (the tentpole's target cost). Emits the "two_pass" section
/// of BENCH_train.json: steps/s + pool-hit-rate per point, the per-row
/// baseline per m, and the acceptance flag (two-pass beats per-row
/// descent at every m ≥ 100 for at least one α).
fn two_pass_sweep(dims: &Dims) -> Value {
    let (n_classes, d, rows, threads) = (dims.n_classes, dims.d, dims.rows, dims.threads);
    let ms = [50usize, 100, 500];
    let alphas = [2.0f64, 4.0, 8.0];
    let batches = match scale() {
        Scale::Quick => 12usize,
        Scale::Full => 40,
    };
    let mut rng = Rng::new(0x2FA5);
    let mut emb = vec![0.0f32; n_classes * d];
    rng.fill_normal(&mut emb, 0.4);
    let mut hs = vec![0.0f32; rows * d];
    rng.fill_normal(&mut hs, 1.0);

    let mut per_row = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n_classes, None);
    Sampler::reset_embeddings(&mut per_row, &emb, n_classes, d);
    per_row.set_obs_enabled(false);

    println!(
        "\ntwo-pass sweep: {n_classes} classes × d={d}, batch {rows}, {batches} batches/point"
    );
    println!(
        "{:<22} {:>12} {:>12} {:>10}",
        "sampler", "batches/s", "negatives/s", "hit rate"
    );
    let mut baseline = Vec::new();
    let mut points = Vec::new();
    let mut beats_at_m_ge_100 = true;
    for &m in &ms {
        let base = sampler_batches_per_s(&per_row, &hs, rows, d, n_classes, m, threads, batches);
        println!(
            "{:<22} {:>12.1} {:>12.0} {:>10}",
            format!("per-row m={m}"),
            base,
            base * (rows * m) as f64,
            "-"
        );
        baseline.push(Value::object(vec![
            ("m", Value::num(m as f64)),
            ("steps_per_s", Value::num(base)),
        ]));
        let mut best = 0.0f64;
        for &alpha in &alphas {
            let mut two = TwoPassKernelSampler::new(
                QuadraticMap::new(d, 100.0),
                n_classes,
                None,
                alpha,
            );
            Sampler::reset_embeddings(&mut two, &emb, n_classes, d);
            let sps = sampler_batches_per_s(&two, &hs, rows, d, n_classes, m, threads, batches);
            let obs = two.obs();
            let draws = (obs.hit_total() + obs.miss_total()).max(1);
            let hit_rate = obs.hit_total() as f64 / draws as f64;
            println!(
                "{:<22} {:>12.1} {:>12.0} {:>9.1}%",
                format!("two-pass m={m} α={alpha}"),
                sps,
                sps * (rows * m) as f64,
                100.0 * hit_rate
            );
            best = best.max(sps);
            points.push(Value::object(vec![
                ("m", Value::num(m as f64)),
                ("pool_factor", Value::num(alpha)),
                ("steps_per_s", Value::num(sps)),
                ("speedup_vs_per_row", Value::num(sps / base.max(1e-12))),
                ("pool_hit_rate", Value::num(hit_rate)),
                ("pool_size", Value::num(obs.pool_size())),
                ("pool_unique", Value::num(obs.pool_unique())),
                ("fallback_rows", Value::num(obs.fallback_total() as f64)),
            ]));
        }
        if m >= 100 && best <= base {
            beats_at_m_ge_100 = false;
        }
        if m >= 100 {
            println!(
                "  (acceptance m={m}: best two-pass {:.1} vs per-row {:.1} batches/s — {})",
                best,
                base,
                if best > base { "beats" } else { "MISSES" }
            );
        }
    }
    Value::object(vec![
        ("batches_per_point", Value::num(batches as f64)),
        ("per_row_baseline", Value::Array(baseline)),
        ("points", Value::Array(points)),
        ("beats_per_row_at_m_ge_100", Value::Bool(beats_at_m_ge_100)),
    ])
}

fn main() {
    let dims = match scale() {
        Scale::Quick => Dims {
            n_classes: 4_000,
            d: 16,
            rows: 48,
            m: 16,
            steps: 120,
            device_reps: 700,
            threads: 2,
        },
        Scale::Full => Dims {
            n_classes: 50_000,
            d: 32,
            rows: 128,
            m: 32,
            steps: 400,
            device_reps: 4_000,
            threads: 4,
        },
    };
    println!(
        "train pipeline: {} classes × d={}, batch {} × m={}, {} steps",
        dims.n_classes, dims.d, dims.rows, dims.m, dims.steps
    );

    let seq = run_depth(1, &dims);
    let pipe = run_depth(2, &dims);

    let row = |name: &str, s: &RunStats| BenchRow {
        name: name.to_string(),
        mean_s: s.wall_s / dims.steps as f64,
        p50_s: s.wall_s / dims.steps as f64,
        p95_s: s.wall_s / dims.steps as f64,
        iters: dims.steps,
        items_per_iter: Some((dims.rows * dims.m) as f64),
    };
    let seq_row = row("depth 1 (sequential)", &seq);
    let pipe_row = row("depth 2 (overlapped)", &pipe);
    let rows = [seq_row.clone(), pipe_row.clone()];
    print_table("steps (throughput column = negatives drawn/s)", &rows);
    print_speedup("pipelined vs sequential", &seq_row, &pipe_row);

    let report = |name: &str, s: &RunStats| {
        println!(
            "{name}: wall {:.3}s  device {:.3}s  sample visible {:.3}s / hidden {:.3}s  \
             publish visible {:.3}s / hidden {:.3}s",
            s.wall_s,
            s.device_s,
            s.sample_visible_s,
            s.sample_hidden_s,
            s.publish_visible_s,
            s.publish_hidden_s
        );
    };
    report("depth 1", &seq);
    report("depth 2", &pipe);
    let hidden_frac = if seq.sample_visible_s > 0.0 {
        1.0 - pipe.sample_visible_s / seq.sample_visible_s
    } else {
        0.0
    };
    println!(
        "(acceptance shape: depth 2 hides {:.0}% of the sampling wall behind the device step; \
         publish rides the worker)",
        100.0 * hidden_frac
    );

    let depth_json = |s: &RunStats| {
        Value::object(vec![
            ("steps_per_s", Value::num(dims.steps as f64 / s.wall_s.max(1e-12))),
            ("wall_s", Value::num(s.wall_s)),
            ("device_s", Value::num(s.device_s)),
            ("sample_visible_s", Value::num(s.sample_visible_s)),
            ("sample_hidden_s", Value::num(s.sample_hidden_s)),
            ("publish_visible_s", Value::num(s.publish_visible_s)),
            ("publish_hidden_s", Value::num(s.publish_hidden_s)),
        ])
    };
    let doc = Value::object(vec![
        ("bench", Value::str("train_pipeline")),
        (
            "scale",
            Value::str(match scale() {
                Scale::Quick => "quick",
                Scale::Full => "full",
            }),
        ),
        ("steps", Value::num(dims.steps as f64)),
        ("depth1", depth_json(&seq)),
        ("depth2", depth_json(&pipe)),
        ("speedup_pipelined_vs_sequential", Value::num(seq.wall_s / pipe.wall_s.max(1e-12))),
        ("sample_wall_hidden_fraction", Value::num(hidden_frac)),
        ("two_pass", two_pass_sweep(&dims)),
    ]);
    write_json_value("train", &doc);
}
