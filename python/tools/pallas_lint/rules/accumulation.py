"""ACC — the ops accumulation-order contract.

`rust/src/ops/` pins one accumulation order per reduction (blocked lanes
for dots, strictly sequential prefix sums, f64 long sums) so that every
probability in the system is a pure function of its inputs. A raw
`for`-loop float reduction anywhere else is a second, unpinned order:
it can silently disagree with the ops result at the 1e-15 level that the
eq. (2) q-exactness regression tests bound, and it re-opens the
duplicated-inner-loop class PR 4 deleted. Hot paths must call `ops::`
primitives (`dot*`, `dot_many*`, `fill_cum*`, `axpy*`); intentionally
sequential cold-path loops get a waiver with a reason.
"""

from __future__ import annotations

import re

from pallas_lint.frontend import IDENT, PUNCT, SourceFile, snippet
from pallas_lint.rules import Finding, Rule

# `acc += <expr>;` where <expr> reads data (indexing, call, field or
# multiply) — not a bare counter bump.
_COMPOUND = re.compile(
    r"(?:^|[^+\-*/%&|^])\b(?P<target>\*?\s*[A-Za-z_]\w*(?:\s*\[[^\]]*\])?)\s*"
    r"\+=\s*(?P<rhs>[^;]+);"
)
_RHS_READS_DATA = re.compile(r"[\[(*.]")


def _float_zero_init(body: str, ident: str) -> bool:
    """Is `ident` initialized as a float accumulator in this function?"""
    pat = (
        rf"let\s+(?:mut\s+)?{re.escape(ident)}\s*"
        r"(?::\s*f(?:32|64)\s*)?=\s*0(?:\.\d*)?(?:_?f(?:32|64))?\s*;"
    )
    if re.search(pat, body):
        # integer zero (`= 0;` with no float type/suffix) is a counter,
        # not a float accumulator
        m = re.search(pat, body)
        text = m.group(0)
        return ("f32" in text) or ("f64" in text) or ("." in text)
    # explicitly typed float binding initialized from something else
    return bool(
        re.search(rf"let\s+(?:mut\s+)?{re.escape(ident)}\s*:\s*f(?:32|64)\b", body)
    )


class AccumulationContract(Rule):
    id = "ACC"
    name = "accumulation-contract"
    summary = "raw for-loop float reductions outside rust/src/ops/"
    contract = (
        "ops accumulation-order contract (README 'The ops layer'): every "
        "float reduction on a hot path goes through ops:: primitives so "
        "the eq. (2) probabilities are a pure function of the inputs"
    )

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("rust/src/") and not relpath.startswith(
            "rust/src/ops/"
        )

    def check(self, sf: SourceFile) -> list[Finding]:
        findings: list[Finding] = []
        code = sf.code
        seen_lines: set[int] = set()
        for i, tok in enumerate(code):
            if not (tok.kind == IDENT and tok.text == "for"):
                continue
            if sf.in_test(tok.line):
                continue
            # body `{` of the for loop: first `{` with ()/[] closed
            depth = 0
            j = i + 1
            body_open = -1
            while j < len(code):
                c = code[j]
                if c.kind == PUNCT:
                    if c.text in "([":
                        depth += 1
                    elif c.text in ")]":
                        depth -= 1
                    elif c.text == "{" and depth == 0:
                        body_open = j
                        break
                    elif c.text == ";" and depth == 0:
                        break
                j += 1
            if body_open < 0:
                continue
            body_close = sf.match_brace(body_open)
            lo, hi = code[body_open].line, code[body_close].line
            fn = sf.function_at(tok.line)
            fn_body = (
                "\n".join(sf.lines[fn.start_line - 1 : fn.end_line])
                if fn
                else "\n".join(sf.lines[max(0, lo - 40) : hi])
            )
            for m in _COMPOUND.finditer("\n".join(sf.lines[lo - 1 : hi])):
                target = m.group("target").lstrip("*").strip()
                base = re.split(r"[\s\[]", target, 1)[0]
                rhs = m.group("rhs")
                # the decimal point of a float literal is not a field access
                rhs_no_nums = re.sub(r"\b\d[\d_]*\.\d*", "", rhs)
                if not _RHS_READS_DATA.search(rhs_no_nums):
                    continue
                if not _float_zero_init(fn_body, base):
                    continue
                line = lo + "\n".join(sf.lines[lo - 1 : hi])[: m.start()].count("\n")
                if line in seen_lines or sf.in_test(line):
                    continue
                seen_lines.add(line)
                findings.append(
                    Finding(
                        rule=self.id,
                        file=sf.path,
                        line=line,
                        message=(
                            f"raw float reduction `{base} += ...` in a for loop "
                            "outside ops:: — hot-path reductions must use "
                            "ops::dot/dot_many/fill_cum (pinned accumulation order)"
                        ),
                        snippet=snippet(sf, line),
                    )
                )
        return findings
