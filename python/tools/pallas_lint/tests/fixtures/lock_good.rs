// pallas-lint fixture — must NOT trip LOCK: disciplined variants of every
// pattern lock_bad.rs breaks.

use std::sync::Mutex;

pub struct S {
    queue: Mutex<Vec<u32>>,
    state: Mutex<u32>,
    a: Mutex<u32>,
    b: Mutex<u32>,
}

pub struct Reader;
impl Reader {
    pub fn pinned(&self) -> u64 {
        0
    }
}

impl S {
    /// Sequential sections: the first guard is dropped before relocking.
    pub fn relock_after_drop(&self) {
        let g = self.queue.lock().unwrap();
        drop(g);
        let g = self.queue.lock().unwrap();
        drop(g);
    }

    /// Scope-bounded guards never overlap.
    pub fn scoped_sections(&self) {
        {
            let _g = self.a.lock().unwrap();
        }
        {
            let _g = self.b.lock().unwrap();
        }
    }

    /// A statement-temporary guard is released at the semicolon.
    pub fn temporaries(&self) {
        self.queue.lock().unwrap().push(1);
        self.state.lock().unwrap().checked_add(1).map(|_| ()).unwrap_or(());
    }

    /// The pinned generation is released before any lock.
    pub fn pin_then_lock(&self, reader: &Reader) {
        let snap = reader.pinned();
        let _ = snap;
        drop(snap);
        let g = self.state.lock().unwrap();
        drop(g);
    }

    /// Consistent a-then-b order in every function: acyclic graph.
    pub fn order_ab_one(&self) {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        drop(gb);
        drop(ga);
    }

    pub fn order_ab_two(&self) {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        drop(gb);
        drop(ga);
    }
}
