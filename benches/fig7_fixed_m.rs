//! Figure 7 (appendix) — **fixed-m distribution comparison, per dataset**
//! (the multi-panel companion of Figure 4).
//!
//! `cargo bench --bench fig7_fixed_m` / `KSS_BENCH_SCALE=full ...`

use kss::bench_harness::{engine_or_exit, print_series, scale, Scale};
use kss::coordinator::experiment::{run_grid, GridSpec};
use kss::coordinator::TrainConfig;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    let panels: Vec<(&str, TrainConfig, usize)> = match scale() {
        Scale::Quick => vec![
            (
                "tiny-recsys m=8",
                TrainConfig {
                    model: "tiny".into(),
                    epochs: 3,
                    train_size: 960,
                    valid_size: 320,
                    eval_batches: 8,
                    eval_every: 40,
                    ..Default::default()
                },
                8,
            ),
            (
                "tiny-lm m=4",
                TrainConfig {
                    model: "tiny-lm".into(),
                    epochs: 2,
                    train_size: 4_000,
                    valid_size: 1_000,
                    eval_batches: 8,
                    eval_every: 60,
                    ..Default::default()
                },
                4,
            ),
        ],
        Scale::Full => vec![
            (
                "ptb m=32",
                TrainConfig {
                    model: "ptb".into(),
                    epochs: 3,
                    train_size: 120_000,
                    valid_size: 24_000,
                    eval_batches: 8,
                    eval_every: 100,
                    ..Default::default()
                },
                32,
            ),
            (
                "yt10k m=32",
                TrainConfig {
                    model: "yt10k".into(),
                    epochs: 3,
                    train_size: 40_000,
                    valid_size: 6_400,
                    eval_batches: 8,
                    eval_every: 150,
                    ..Default::default()
                },
                32,
            ),
            (
                "yt100k m=64",
                TrainConfig {
                    model: "yt100k".into(),
                    epochs: 1,
                    train_size: 40_000,
                    valid_size: 6_400,
                    eval_batches: 8,
                    eval_every: 150,
                    ..Default::default()
                },
                64,
            ),
        ],
    };

    for (label, base, m) in panels {
        println!("\n==== Figure 7 — {label} ====");
        let samplers: Vec<String> = if base.model.contains("lm") || base.model == "ptb" {
            kss::sampler::LM_SAMPLERS.iter().map(|s| s.to_string()).collect()
        } else {
            vec!["uniform".into(), "unigram".into(), "quadratic".into(), "softmax".into()]
        };
        let grid = GridSpec { base, samplers, ms: vec![m], include_full: true };
        let summaries = run_grid(&engine, &grid, Some(std::path::Path::new("runs/fig7")))?;
        for s in &summaries {
            let pts: Vec<(f64, f64)> = s.curve.iter().map(|p| (p.epoch, p.loss)).collect();
            print_series(&s.label(), &pts);
        }
    }
    println!("\nshape to check: convergence speeds match; only the plateaus (bias)");
    println!("separate the distributions.");
    Ok(())
}
