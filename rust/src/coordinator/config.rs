//! Experiment configuration and dataset construction.
//!
//! A [`TrainConfig`] fully determines a run: model (manifest entry), sampler,
//! sample size m, schedule, corpus scale and seeds. Configs can be built
//! from CLI flags (`main.rs`) or programmatically (benches); either way the
//! run is reproducible byte-for-byte from the config alone.

use crate::data::{synptb::SynPtb, youtube::YouTube, Dataset};
use crate::runtime::{ModelKind, ModelSpec};
use crate::util::json::Value;
use anyhow::Result;

/// Everything one training run needs.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Manifest model name (e.g. "ptb", "yt10k", "tiny").
    pub model: String,
    /// Sampler name (see sampler::build_sampler) or "full" for the
    /// full-softmax baseline.
    pub sampler: String,
    /// Negatives per example.
    pub m: usize,
    /// SGD learning rate.
    pub lr: f32,
    pub epochs: usize,
    /// Train-set scale: tokens (lm) or events (recsys).
    pub train_size: usize,
    /// Validation-set scale.
    pub valid_size: usize,
    /// Cap on steps per epoch (0 = no cap) — keeps figure sweeps tractable.
    pub max_steps_per_epoch: usize,
    /// Evaluate every k steps (0 = once per epoch).
    pub eval_every: usize,
    /// Cap on eval batches per evaluation (0 = all).
    pub eval_batches: usize,
    /// Sampling threads (0 = auto).
    pub threads: usize,
    /// Master seed: data, init and sampling streams derive from it.
    pub seed: u64,
    /// Training-pipeline depth: 1 = sequential stages (bitwise identical
    /// to the pre-pipeline loop), 2 = the next step's encode + negative
    /// sampling overlap the current step's device execute, with q read
    /// from a one-step-stale snapshot generation (eq. (2) corrections use
    /// the q actually sampled, so the estimator stays exact — see
    /// `coordinator::pipeline`). Values > 2 are clamped to 2.
    pub pipeline_depth: usize,
    /// Route the adaptive kernel-tree samplers through the serve snapshot
    /// layer (one shared tree for training *and* serving; single update
    /// sweep per step). `false` restores the pre-pipeline private-tree
    /// sampler — kept as the bitwise-equivalence reference for tests, not
    /// exposed on the CLI.
    pub unified_tree: bool,
    /// Pool divisor α of the two-pass samplers (`*-2pass`): the shared
    /// candidate pool holds P = ⌈B·m/α⌉ slots. Larger α = smaller pool =
    /// cheaper pass 1 but coarser coverage. Ignored by every other
    /// sampler kind.
    pub pool_factor: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "tiny".into(),
            sampler: "uniform".into(),
            m: 8,
            lr: 0.2,
            epochs: 1,
            train_size: 8_000,
            valid_size: 1_000,
            max_steps_per_epoch: 0,
            eval_every: 0,
            eval_batches: 20,
            threads: 0,
            seed: 42,
            pipeline_depth: 1,
            unified_tree: true,
            pool_factor: 4.0,
        }
    }
}

impl TrainConfig {
    /// Identifier used in logs/metrics files. Pipeline depth is part of
    /// the id only when it changes results (depth ≥ 2 samples one
    /// generation stale; depth 1 is the sequential reference).
    pub fn run_id(&self) -> String {
        let depth = if self.pipeline_depth > 1 {
            format!("_p{}", self.pipeline_depth.min(2))
        } else {
            String::new()
        };
        if self.sampler == "full" {
            format!("{}_full_lr{}_s{}", self.model, self.lr, self.seed)
        } else {
            format!(
                "{}_{}_m{}_lr{}_s{}{}",
                self.model, self.sampler, self.m, self.lr, self.seed, depth
            )
        }
    }

    /// JSON form (written at the head of every metrics file).
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("model", Value::str(&self.model)),
            ("sampler", Value::str(&self.sampler)),
            ("m", Value::num(self.m as f64)),
            ("lr", Value::num(self.lr as f64)),
            ("epochs", Value::num(self.epochs as f64)),
            ("train_size", Value::num(self.train_size as f64)),
            ("valid_size", Value::num(self.valid_size as f64)),
            ("max_steps_per_epoch", Value::num(self.max_steps_per_epoch as f64)),
            ("eval_every", Value::num(self.eval_every as f64)),
            ("eval_batches", Value::num(self.eval_batches as f64)),
            ("threads", Value::num(self.threads as f64)),
            ("seed", Value::num(self.seed as f64)),
            ("pipeline_depth", Value::num(self.pipeline_depth as f64)),
            ("unified_tree", Value::Bool(self.unified_tree)),
            ("pool_factor", Value::num(self.pool_factor)),
        ])
    }

    /// Reasonable per-model defaults for lr and corpus scale (overridable).
    pub fn with_model_defaults(mut self, spec: &ModelSpec) -> TrainConfig {
        match spec.kind {
            ModelKind::Lm => {
                if self.lr == 0.0 {
                    self.lr = 0.5;
                }
            }
            ModelKind::Recsys => {
                if self.lr == 0.0 {
                    self.lr = 0.25;
                }
            }
        }
        self
    }
}

/// Build the dataset a model spec calls for.
pub fn build_dataset(spec: &ModelSpec, cfg: &TrainConfig) -> Result<Box<dyn Dataset>> {
    let seed = cfg.seed ^ 0xDA7A_5EED;
    Ok(match spec.kind {
        ModelKind::Lm => Box::new(SynPtb::generate(
            spec.n_classes,
            spec.batch,
            spec.seq_len.ok_or_else(|| anyhow::anyhow!("lm spec missing seq_len"))?,
            cfg.train_size,
            cfg.valid_size,
            seed,
        )),
        ModelKind::Recsys => Box::new(YouTube::generate(
            spec.n_classes,
            spec.n_user_features
                .ok_or_else(|| anyhow::anyhow!("recsys spec missing n_user_features"))?,
            cfg.train_size,
            cfg.valid_size,
            spec.batch,
            seed,
        )),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_id_distinguishes_configs() {
        let a = TrainConfig { sampler: "quadratic".into(), m: 32, ..Default::default() };
        let b = TrainConfig { sampler: "quadratic".into(), m: 64, ..Default::default() };
        let c = TrainConfig { sampler: "full".into(), ..Default::default() };
        assert_ne!(a.run_id(), b.run_id());
        assert!(c.run_id().contains("full") && !c.run_id().contains("_m"));
        // depth changes results only at >= 2, so only then does it tag the id
        let d2 = TrainConfig { sampler: "quadratic".into(), pipeline_depth: 2, ..a.clone() };
        assert!(d2.run_id().ends_with("_p2"), "{}", d2.run_id());
        assert!(!a.run_id().contains("_p"), "{}", a.run_id());
        assert_ne!(a.run_id(), d2.run_id());
    }

    #[test]
    fn json_roundtrip_has_all_fields() {
        let cfg = TrainConfig::default();
        let v = cfg.to_json();
        for key in ["model", "sampler", "m", "lr", "epochs", "seed"] {
            assert!(v.get(key).is_some(), "missing {key}");
        }
    }
}
