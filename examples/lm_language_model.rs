//! End-to-end driver (the repository's validation run, recorded in
//! EXPERIMENTS.md): train the LSTM language model on the synthetic
//! Penn-Tree-Bank corpus with kernel based sampling, for a few hundred
//! steps, and log the full-softmax loss/perplexity curve.
//!
//! The model is the paper's PTB setup at CPU scale: vocab 10,000, d = 64,
//! B×T = 16×25 = 400 softmax rows per step, m = 32 negatives per row drawn
//! from the quadratic kernel tree (O(D log n) per draw). A uniform-sampling
//! run of the same length is included for contrast, plus the exact-softmax
//! oracle — the three-way comparison at the heart of the paper.
//!
//! ```sh
//! cargo run --release --example lm_language_model            # default ~400 steps
//! KSS_LM_STEPS=100 cargo run --release --example lm_language_model
//! ```

use kss::coordinator::{MetricsSink, TrainConfig, Trainer};
use kss::runtime::Engine;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let steps: usize = std::env::var("KSS_LM_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(400);
    let engine = Engine::new(Path::new("artifacts"))?;

    println!("LSTM LM on synthetic PTB: vocab 10k, d 64, {steps} steps, m = 32\n");
    let mut results = Vec::new();
    for sampler in ["quadratic", "uniform", "softmax"] {
        let cfg = TrainConfig {
            model: "ptb".into(),
            sampler: sampler.into(),
            m: 32,
            lr: 0.5,
            epochs: 1,
            train_size: (steps + 1) * 16 * 25 + 16, // exactly `steps` windows
            valid_size: 30_000,
            max_steps_per_epoch: steps,
            eval_every: (steps / 8).max(1),
            eval_batches: 8,
            seed: 42,
            ..Default::default()
        };
        let run_id = cfg.run_id();
        println!("-- {run_id}");
        let mut sink = MetricsSink::to_dir(Path::new("runs"), &run_id)?;
        let mut trainer = Trainer::new(&engine, cfg)?;
        let res = trainer.train(&mut sink)?;
        println!("   loss curve (step, full-softmax CE, perplexity):");
        for p in &res.curve {
            println!("     step {:>5}  loss {:.4}  ppl {:>9.2}", p.step, p.loss, p.ppl());
        }
        println!("   phase breakdown:\n{}", indent(&trainer.phases.report()));
        results.push((sampler, res));
    }

    println!("\nsummary after {steps} steps (full-softmax eval):");
    println!("{:<12} {:>10} {:>12}", "sampler", "loss", "perplexity");
    for (sampler, res) in &results {
        println!("{:<12} {:>10.4} {:>12.2}", sampler, res.final_loss, res.final_loss.exp());
    }
    println!("\nExpected shape (paper Fig. 4): softmax and quadratic track each");
    println!("other; uniform lags with the same m because its estimator is biased.");
    Ok(())
}

fn indent(s: &str) -> String {
    s.lines().map(|l| format!("     {l}\n")).collect()
}
