// pallas-lint REG fixture (inconsistent): "phantom" has no match arm,
// "orphan" has no registry entry, and README/main.rs drift (see siblings).

pub struct SamplerInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

pub const SAMPLER_REGISTRY: &[SamplerInfo] = &[
    SamplerInfo { name: "uniform", summary: "uniform over classes" },
    SamplerInfo { name: "phantom", summary: "advertised but unbuildable" },
];

pub fn build_sampler(name: &str) -> Result<u32, String> {
    match name {
        "uniform" => Ok(0),
        "orphan" => Ok(9),
        other => Err(format!("unknown sampler '{other}'")),
    }
}
