//! Sharded kernel sampling: S independent sub-trees behind a mass router.
//!
//! The class space `[0, n)` is split into S contiguous ranges; shard `s`
//! owns a [`KernelTreeSampler`] over its local ids `[0, n_s)`. A draw picks
//! the shard from the top-level CDF over the per-shard root masses
//! `M_s = ⟨φ(h), z_s(root)⟩`, then descends inside it, and rescales the
//! shard-local probability:
//!
//! ```text
//! q(j) = P(shard s) · P(j | shard s) = (M_s / Σ_t M_t) · (K(h, w_j) / M_s)
//!      = K(h, w_j) / Σ_t M_t
//! ```
//!
//! — exactly the unsharded eq. (8) distribution, since the unsharded root
//! mass is the same sum `Σ_t M_t` (up to f64 summation order; the property
//! test pins the tolerance). The zero-mass guards compose the same way:
//! when `Σ M_t` degenerates the router falls back to a uniform shard choice
//! with probability 1/S, the shard's own guarded descent supplies a
//! strictly positive conditional, and the reported q is the product of the
//! probabilities actually used — so q > 0 always, sharded or not.
//!
//! Shards are independent for writes too: `update_many` routes each class
//! to its shard (parallel across shards via [`update_many_parallel`]), and
//! the serving layer gives every shard its own snapshot store so a hot
//! shard can publish without touching the others.
//!
//! [`update_many_parallel`]: ShardedKernelSampler::update_many_parallel

use crate::ops;
use crate::sampler::kernel::tree::{
    sanitize_mass, step_down_to_positive, DrawScratch, KernelTreeSampler, TreeView,
};
use crate::sampler::kernel::FeatureMap;
use crate::sampler::{row_rng, BatchSampleInput, Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use crate::util::threadpool::{par_chunks_mut, Pool};
use anyhow::Result;

/// Contiguous shard boundaries over `n` classes: `offsets[s]..offsets[s+1]`
/// is shard `s`'s global class range (as even as integer division allows).
pub fn shard_offsets(n: usize, shards: usize) -> Vec<u32> {
    let shards = shards.clamp(1, n.max(1));
    (0..=shards).map(|s| (s * n / shards) as u32).collect()
}

/// Shard id owning a global class under contiguous `offsets` — the single
/// routing rule shared by the sampler, the writer bundle
/// ([`crate::serve::ShardSet`]) and retrieval, so a layout change cannot
/// desynchronize them.
#[inline]
pub fn shard_of_class(offsets: &[u32], class: usize) -> usize {
    debug_assert!(class < *offsets.last().expect("offsets non-empty") as usize);
    offsets.partition_point(|&o| (o as usize) <= class) - 1
}

/// Split a global-class update batch (`classes` sorted + dedup, `rows` flat
/// len×d) into per-shard `(local classes, rows)` parts, empty where a shard
/// is untouched.
pub fn split_updates_by_shard(
    offsets: &[u32],
    d: usize,
    classes: &[usize],
    rows: &[f32],
) -> Vec<(Vec<usize>, Vec<f32>)> {
    debug_assert_eq!(rows.len(), classes.len() * d);
    let mut parts: Vec<(Vec<usize>, Vec<f32>)> =
        (0..offsets.len() - 1).map(|_| (Vec::new(), Vec::new())).collect();
    for (i, &class) in classes.iter().enumerate() {
        let sid = shard_of_class(offsets, class);
        parts[sid].0.push(class - offsets[sid] as usize);
        parts[sid].1.extend_from_slice(&rows[i * d..(i + 1) * d]);
    }
    parts
}

/// Reusable per-caller router state: one [`DrawScratch`] per shard plus the
/// φ(h)/mass/CDF buffers. Checked out of a freelist like the tree's own
/// scratches, so steady-state sampling allocates nothing.
pub struct ShardScratch {
    phi_h: Vec<f64>,
    scratches: Vec<DrawScratch>,
    /// Whether shard s's scratch is primed for the current example.
    primed: Vec<bool>,
    /// Raw per-shard root partitions (reused to prime a shard's scratch
    /// without recomputing the O(D) dot), their sanitized versions, and
    /// the sanitized inclusive prefix sums the router draws from.
    raw_totals: Vec<f64>,
    masses: Vec<f64>,
    cum: Vec<f64>,
}

/// Draw `m` samples for one example from a set of shard trees, writing
/// `(global class, merged q)` into `out` (appended, not cleared — the
/// caller owns clearing). Shared by [`ShardedKernelSampler`] and the serve
/// workers, which operate on snapshot trees. Takes read-only [`TreeView`]s:
/// the type guarantees the router can never touch an update path.
///
/// φ(h) is materialized once and reused to score every shard's root; a
/// shard's descent scratch is primed lazily, only when a draw first lands
/// on it.
pub fn draw_from_shards<M: FeatureMap>(
    trees: &[TreeView<'_, M>],
    offsets: &[u32],
    h: &[f32],
    m: usize,
    state: &mut ShardScratch,
    rng: &mut Rng,
    out: &mut Sample,
) {
    let s_count = trees.len();
    debug_assert_eq!(offsets.len(), s_count + 1);
    trees[0].feature_map().phi(h, &mut state.phi_h);
    for (s, tree) in trees.iter().enumerate() {
        let raw = tree.partition(&state.phi_h);
        state.raw_totals[s] = raw;
        state.masses[s] = sanitize_mass(raw);
        state.primed[s] = false;
    }
    // router CDF over the sanitized masses: the same ops-layer prefix sum
    // the flat sampler's scratch and `util::rng::Cdf` draw from
    let total = ops::fill_cum_into(&state.masses, &mut state.cum);
    for _ in 0..m {
        // eq. (9) at the router level: shard ∝ its root mass, guarded the
        // same way the tree guards a degenerate branch
        let (sid, p_shard) = if total > 0.0 && total.is_finite() {
            let u = rng.f64() * total;
            let idx = state.cum.partition_point(|&c| c <= u).min(s_count - 1);
            let idx = step_down_to_positive(&state.cum, idx);
            (idx, state.masses[idx] / total)
        } else {
            (rng.below(s_count as u64) as usize, 1.0 / s_count as f64)
        };
        if !state.primed[sid] {
            trees[sid].begin_example_prepared(
                &state.phi_h,
                state.raw_totals[sid],
                &mut state.scratches[sid],
            );
            state.primed[sid] = true;
        }
        let (local, q_local) = trees[sid].draw(h, &mut state.scratches[sid], rng);
        // merged q — the product of the probabilities actually used, which
        // equals K/ΣM in the clean regime and stays > 0 in every other
        let q = (p_shard * q_local).max(f64::MIN_POSITIVE);
        out.push(offsets[sid] + local, q);
    }
}

/// S independent kernel trees behind the mass router (a drop-in
/// [`Sampler`]: `"quadratic-sharded"` / `"rff-sharded"` in configs).
pub struct ShardedKernelSampler<M: FeatureMap + Clone> {
    shards: Vec<KernelTreeSampler<M>>,
    offsets: Vec<u32>,
    n: usize,
    d: usize,
    /// Registry name, `<kernel>-sharded` (derived from the map).
    name: String,
    /// Freelist of router scratch states (same pooling discipline as the
    /// tree's DrawScratch freelist — see [`Pool`]).
    scratch_pool: Pool<ShardScratch>,
}

impl<M: FeatureMap + Clone> ShardedKernelSampler<M> {
    /// Split `n` classes into `shards` contiguous sub-trees. `leaf_size`
    /// as in [`KernelTreeSampler::new`].
    pub fn new(map: M, n: usize, shards: usize, leaf_size: Option<usize>) -> Self {
        assert!(n > 0);
        let name = format!("{}-sharded", map.name());
        let offsets = shard_offsets(n, shards);
        let trees: Vec<KernelTreeSampler<M>> = offsets
            .windows(2)
            .map(|w| KernelTreeSampler::new(map.clone(), (w[1] - w[0]) as usize, leaf_size))
            .collect();
        let d = trees[0].embed_dim();
        ShardedKernelSampler { shards: trees, offsets, n, d, name, scratch_pool: Pool::new() }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// The shard trees (the serve layer wraps each in its own publisher).
    pub fn shards(&self) -> &[KernelTreeSampler<M>] {
        &self.shards
    }

    /// Consume the sampler into its shard trees.
    pub fn into_shards(self) -> (Vec<KernelTreeSampler<M>>, Vec<u32>) {
        (self.shards, self.offsets)
    }

    /// Shard id owning a global class.
    #[inline]
    fn shard_of(&self, class: usize) -> usize {
        debug_assert!(class < self.n);
        shard_of_class(&self.offsets, class)
    }

    /// Allocate a router scratch sized for these shards.
    pub fn new_scratch(&self) -> ShardScratch {
        scratch_for(&self.views())
    }

    /// Read-only views over the shard trees (what the draw path consumes).
    fn views(&self) -> Vec<TreeView<'_, M>> {
        self.shards.iter().map(|t| t.view()).collect()
    }

    fn take_scratch(&self) -> ShardScratch {
        self.scratch_pool.take(|| self.new_scratch())
    }

    fn put_scratch(&self, s: ShardScratch) {
        self.scratch_pool.put(s);
    }

    /// `update_many` with the independent shards swept concurrently — the
    /// parallel-update payoff of sharding (each sub-tree's bottom-up sweep
    /// touches disjoint arenas). `threads` is a real concurrency cap:
    /// touched shards are dealt round-robin onto at most that many worker
    /// threads (0/1 runs serially). Results never depend on `threads` —
    /// shard states are disjoint.
    pub fn update_many_parallel(&mut self, classes: &[usize], rows: &[f32], threads: usize) {
        debug_assert_eq!(rows.len(), classes.len() * self.d);
        if classes.is_empty() {
            return;
        }
        let parts = split_updates_by_shard(&self.offsets, self.d, classes, rows);
        let touched = parts.iter().filter(|(cl, _)| !cl.is_empty()).count();
        let threads = threads.max(1).min(touched);
        if threads <= 1 {
            for (shard, (cl, rw)) in self.shards.iter_mut().zip(&parts) {
                if !cl.is_empty() {
                    shard.update_many(cl, rw);
                }
            }
            return;
        }
        let mut groups: Vec<Vec<(&mut KernelTreeSampler<M>, &(Vec<usize>, Vec<f32>))>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (i, (shard, part)) in
            self.shards.iter_mut().zip(&parts).filter(|(_, (cl, _))| !cl.is_empty()).enumerate()
        {
            groups[i % threads].push((shard, part));
        }
        std::thread::scope(|scope| {
            for group in groups {
                scope.spawn(move || {
                    for (shard, (cl, rw)) in group {
                        shard.update_many(cl, rw);
                    }
                });
            }
        });
    }

    /// Merged top-k across shards: per-shard beam descents, then the
    /// shared deterministic merge (see [`crate::serve::topk`]).
    pub fn topk_beam(&self, h: &[f32], k: usize, beam_width: usize) -> Vec<(u32, f64)> {
        crate::serve::topk::merge_shard_topk(
            self.shards
                .iter()
                .enumerate()
                .map(|(sid, shard)| (self.offsets[sid], shard.topk_beam(h, k, beam_width)))
                .collect(),
            k,
        )
    }
}

/// Build a [`ShardScratch`] for a specific shard set (serve workers build
/// theirs from snapshot trees rather than a `ShardedKernelSampler`).
pub fn scratch_for<M: FeatureMap>(trees: &[TreeView<'_, M>]) -> ShardScratch {
    let s = trees.len();
    ShardScratch {
        phi_h: vec![0.0; trees[0].feature_map().dim()],
        scratches: trees.iter().map(|t| t.new_scratch()).collect(),
        primed: vec![false; s],
        raw_totals: vec![0.0; s],
        masses: vec![0.0; s],
        cum: vec![0.0; s],
    }
}

impl<M: FeatureMap + Clone> Sampler for ShardedKernelSampler<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let h = input.h.ok_or_else(|| anyhow::anyhow!("sharded kernel sampler needs h"))?;
        anyhow::ensure!(h.len() == self.d, "h len {} != d {}", h.len(), self.d);
        out.clear();
        let trees = self.views();
        let mut state = self.take_scratch();
        draw_from_shards(&trees, &self.offsets, h, m, &mut state, rng, out);
        self.put_scratch(state);
        Ok(())
    }

    /// Batched engine: one router scratch per worker, row streams from
    /// [`row_rng`] — bit-identical to the per-row [`Sampler::sample`] loop.
    fn sample_batch(
        &self,
        inputs: &BatchSampleInput,
        m: usize,
        step_seed: u64,
        out: &mut [Sample],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == inputs.n,
            "out has {} slots, batch has {} rows",
            out.len(),
            inputs.n
        );
        inputs.validate(self.name(), self.needs())?;
        anyhow::ensure!(inputs.d == self.d, "batch h dim {} != sampler d {}", inputs.d, self.d);
        let h_all = inputs.h.expect("validated: sharded sampler needs h");
        let trees = self.views();
        par_chunks_mut(out, inputs.threads, |base, chunk| {
            let mut state = self.take_scratch();
            for (k, slot) in chunk.iter_mut().enumerate() {
                let i = base + k;
                let h = &h_all[i * self.d..(i + 1) * self.d];
                let mut rng = row_rng(step_seed, i);
                slot.clear();
                draw_from_shards(&trees, &self.offsets, h, m, &mut state, &mut rng, slot);
            }
            self.put_scratch(state);
        });
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let h = input.h?;
        let phi_h = self.shards[0].phi_query(h);
        let total: f64 = self.shards.iter().map(|t| sanitize_mass(t.partition(&phi_h))).sum();
        // eq. (2) q-positivity: every shard mass sanitized to zero means
        // no defined distribution — decline rather than return inf/NaN
        if !(total > 0.0) {
            return None;
        }
        let sid = self.shard_of(class as usize);
        let local = class - self.offsets[sid];
        let k = self.shards[sid].feature_map().kernel(h, self.shards[sid].emb_row(local as usize));
        Some(k / total)
    }

    fn update(&mut self, class: usize, w_new: &[f32]) {
        let sid = self.shard_of(class);
        let local = class - self.offsets[sid] as usize;
        self.shards[sid].update(local, w_new);
    }

    /// The trait hook (trainer path) sweeps shards concurrently up to the
    /// machine's default worker count — this is the parallel-update payoff
    /// the sharding exists for, and results cannot depend on it (disjoint
    /// shard states).
    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        let threads = crate::util::threadpool::default_threads();
        self.update_many_parallel(classes, rows, threads);
    }

    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        assert_eq!(n, self.n, "class count changed");
        assert_eq!(d, self.d, "embedding dim changed");
        assert_eq!(w.len(), n * d);
        let offsets = self.offsets.clone();
        for (shard, win) in self.shards.iter_mut().zip(offsets.windows(2)) {
            let (lo, hi) = (win[0] as usize, win[1] as usize);
            shard.reset_embeddings(&w[lo * d..hi * d], hi - lo, d);
        }
    }

    /// The shard set owns S kernel trees; its `update_many` sweeps them
    /// (the trainer's single-sweep accounting counts it as one sweep).
    fn owns_kernel_tree(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::util::stats::chi_square_stat;
    use crate::util::testing::check;

    fn random_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n * d];
        rng.fill_normal(&mut v, 0.5);
        v
    }

    fn exact_dist(map: &QuadraticMap, h: &[f32], emb: &[f32], n: usize, d: usize) -> Vec<f64> {
        let w: Vec<f64> = (0..n).map(|j| map.kernel(h, &emb[j * d..(j + 1) * d])).collect();
        let z: f64 = w.iter().sum();
        w.into_iter().map(|x| x / z).collect()
    }

    #[test]
    fn offsets_partition_the_class_space() {
        for (n, s) in [(10, 3), (7, 7), (100, 8), (5, 16), (1, 1)] {
            let off = shard_offsets(n, s);
            assert_eq!(off[0], 0);
            assert_eq!(*off.last().unwrap() as usize, n);
            assert!(off.windows(2).all(|w| w[0] < w[1]), "empty shard in {off:?}");
        }
    }

    #[test]
    fn sharded_q_matches_unsharded_tree() {
        // the acceptance property: the merged proposal distribution is
        // exactly the unsharded one, to f64 tolerance
        check("sharded q == unsharded q", 12, |g| {
            let n = g.usize_in(4, 96);
            let d = g.usize_in(1, 5);
            let shards = g.usize_in(1, 8.min(n));
            let leaf = g.usize_in(1, 8);
            let mut rng = Rng::new(g.case_seed ^ 0x51);
            let emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, g.f64_in(1.0, 150.0));
            let mut sharded = ShardedKernelSampler::new(map.clone(), n, shards, Some(leaf));
            sharded.reset_embeddings(&emb, n, d);
            let mut unsharded = KernelTreeSampler::new(map.clone(), n, Some(leaf));
            unsharded.reset_embeddings(&emb, n, d);
            let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let input = SampleInput { h: Some(&h), ..Default::default() };
            let expected = exact_dist(&map, &h, &emb, n, d);
            let mut out = Sample::default();
            sharded.sample(&input, 64, &mut rng, &mut out).unwrap();
            assert_eq!(out.classes.len(), 64);
            for (&c, &q) in out.classes.iter().zip(&out.q) {
                assert!((c as usize) < n);
                let wanted = expected[c as usize];
                assert!(
                    (q - wanted).abs() < 1e-9,
                    "class {c}: sharded q {q} vs unsharded {wanted}"
                );
                // and against the unsharded tree's own closed form
                let tq = unsharded.prob(&input, c).unwrap();
                assert!((q - tq).abs() < 1e-9, "class {c}: {q} vs tree {tq}");
            }
            // prob() agrees everywhere, not just on sampled classes
            for c in 0..n as u32 {
                let a = sharded.prob(&input, c).unwrap();
                let b = expected[c as usize];
                assert!((a - b).abs() < 1e-9, "class {c}: {a} vs {b}");
            }
        });
    }

    #[test]
    fn sharded_draw_distribution_chi_square() {
        let (n, d, shards) = (40, 3, 5);
        let mut rng = Rng::new(61);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut sampler = ShardedKernelSampler::new(map.clone(), n, shards, Some(3));
        sampler.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let expected = exact_dist(&map, &h, &emb, n, d);
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut counts = vec![0u64; n];
        let mut out = Sample::default();
        let draws = 200_000usize;
        let m = 50;
        for _ in 0..draws / m {
            sampler.sample(&input, m, &mut rng, &mut out).unwrap();
            for &c in &out.classes {
                counts[c as usize] += 1;
            }
        }
        let stat = chi_square_stat(&counts, &expected, draws as f64);
        // df = n - 1 = 39; mean 39, std sqrt(78) ≈ 8.8 — 39 + 5σ ≈ 83
        assert!(stat < 83.0, "chi-square {stat} too large for df=39");
    }

    #[test]
    fn updates_route_to_the_owning_shard() {
        check("sharded updates == fresh rebuild", 10, |g| {
            let n = g.usize_in(6, 64);
            let d = g.usize_in(1, 4);
            let shards = g.usize_in(2, 6.min(n));
            let mut rng = Rng::new(g.case_seed ^ 0x71);
            let mut emb = random_emb(&mut rng, n, d);
            let map = QuadraticMap::new(d, 100.0);
            let mut sampler = ShardedKernelSampler::new(map.clone(), n, shards, Some(3));
            sampler.reset_embeddings(&emb, n, d);
            // batch update a random subset (sorted + dedup), both parallel
            // and serial paths
            let k = g.usize_in(1, n);
            let mut classes: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut classes);
            classes.truncate(k);
            classes.sort_unstable();
            let mut rows = vec![0.0f32; k * d];
            rng.fill_normal(&mut rows, 0.7);
            let threads = g.usize_in(0, 4);
            sampler.update_many_parallel(&classes, &rows, threads);
            for (i, &c) in classes.iter().enumerate() {
                emb[c * d..(c + 1) * d].copy_from_slice(&rows[i * d..(i + 1) * d]);
            }
            let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let input = SampleInput { h: Some(&h), ..Default::default() };
            let expected = exact_dist(&map, &h, &emb, n, d);
            for c in 0..n as u32 {
                let got = sampler.prob(&input, c).unwrap();
                let want = expected[c as usize];
                assert!((got - want).abs() < 1e-9, "class {c}: {got} vs {want}");
            }
        });
    }

    #[test]
    fn sharded_sample_batch_reproduces_per_row_streams() {
        let (n_classes, d, rows, m) = (32, 3, 11, 7);
        let mut rng = Rng::new(83);
        let emb = random_emb(&mut rng, n_classes, d);
        let mut sampler =
            ShardedKernelSampler::new(QuadraticMap::new(d, 100.0), n_classes, 4, Some(3));
        sampler.reset_embeddings(&emb, n_classes, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let step_seed = 0x54AD;
        let mut per_row: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
        for (i, slot) in per_row.iter_mut().enumerate() {
            let input = SampleInput { h: Some(&hs[i * d..(i + 1) * d]), ..Default::default() };
            let mut r = row_rng(step_seed, i);
            sampler.sample(&input, m, &mut r, slot).unwrap();
        }
        for threads in [0usize, 1, 3, 8] {
            let inputs = BatchSampleInput {
                n: rows,
                d,
                n_classes,
                h: Some(&hs),
                threads,
                ..Default::default()
            };
            let mut batched: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
            sampler.sample_batch(&inputs, m, step_seed, &mut batched).unwrap();
            for (i, (a, b)) in batched.iter().zip(&per_row).enumerate() {
                assert_eq!(a.classes, b.classes, "threads {threads} row {i}");
                assert_eq!(a.q, b.q, "threads {threads} row {i}");
            }
        }
    }

    #[test]
    fn merged_topk_matches_unsharded_exact() {
        let (n, d) = (48, 3);
        let mut rng = Rng::new(91);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut sharded = ShardedKernelSampler::new(map.clone(), n, 5, Some(3));
        sharded.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut exact: Vec<(u32, f64)> = (0..n as u32)
            .map(|c| (c, map.kernel(&h, &emb[c as usize * d..(c as usize + 1) * d])))
            .collect();
        exact.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let k = 10;
        // wide beam: exact within each shard, so the merge is exact overall
        let got = sharded.topk_beam(&h, k, n);
        assert_eq!(got.len(), k);
        for (i, ((gc, gs), (ec, es))) in got.iter().zip(&exact).enumerate() {
            assert_eq!(gc, ec, "rank {i}");
            assert!((gs - es).abs() < 1e-9 * es.max(1.0));
        }
    }
}
