"""The model/experiment configurations that `aot.py` lowers to artifacts.

Scaling notes (DESIGN.md §3): the paper's datasets are PTB (vocab 10k,
d=200 after their own downscaling) and YouTube10k/100k. We keep the class
counts — they are what the sampling problem is about — and shrink d and the
corpus sizes to CPU-PJRT scale. ``tiny*`` configs exist for tests and CI.

``M_SWEEP`` replaces the paper's m ∈ {10, 20, 40, ...} with powers of two.
Each m is a separate HLO artifact (static shapes).
"""

from .model import ModelConfig

# Sample sizes m for the sweeps (one train_sampled artifact each).
M_SWEEP = [8, 16, 32, 64, 128, 256]

# Default sample size used by quickstart/examples.
M_DEFAULT = 32


def _lm(name, n, d, batch, seq_len, abs_logits):
    return ModelConfig(name, "lm", n_classes=n, d=d, batch=batch,
                       seq_len=seq_len, abs_logits=abs_logits)


def _rs(name, n, d, batch, abs_logits):
    return ModelConfig(name, "recsys", n_classes=n, d=d, batch=batch,
                       n_user_features=8, hidden=128, abs_logits=abs_logits)


CONFIGS = {
    # --- experiment-scale configs -----------------------------------------
    # synthetic Penn-Tree-Bank stand-in: vocab 10k (paper: 10k), d scaled
    "ptb": _lm("ptb", n=10_000, d=64, batch=16, seq_len=25, abs_logits=False),
    "ptb-abs": _lm("ptb-abs", n=10_000, d=64, batch=16, seq_len=25, abs_logits=True),
    # YouTube-style retrieval, 10k and 100k catalogs
    "yt10k": _rs("yt10k", n=10_000, d=64, batch=64, abs_logits=False),
    "yt10k-abs": _rs("yt10k-abs", n=10_000, d=64, batch=64, abs_logits=True),
    "yt100k": _rs("yt100k", n=100_000, d=64, batch=64, abs_logits=False),
    "yt100k-abs": _rs("yt100k-abs", n=100_000, d=64, batch=64, abs_logits=True),
    # --- test-scale configs (fast lowering; used by pytest + cargo tests) --
    "tiny": _rs("tiny", n=128, d=16, batch=8, abs_logits=False),
    "tiny-abs": _rs("tiny-abs", n=128, d=16, batch=8, abs_logits=True),
    "tiny-lm": _lm("tiny-lm", n=120, d=16, batch=4, seq_len=6, abs_logits=False),
}

# Which configs the default `make artifacts` builds, and with which m values.
DEFAULT_BUILD = {
    "tiny": [4, 8],
    "tiny-abs": [4],
    "tiny-lm": [4],
    "ptb": M_SWEEP,
    "ptb-abs": M_SWEEP,
    "yt10k": M_SWEEP,
    "yt10k-abs": M_SWEEP,
    "yt100k": M_SWEEP,
    "yt100k-abs": M_SWEEP,
}

# Quick subset for CI / smoke runs (`python -m compile.aot --quick`).
QUICK_BUILD = {
    "tiny": [4, 8],
    "tiny-abs": [4],
    "tiny-lm": [4],
}
