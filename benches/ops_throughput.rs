//! Ops-layer throughput — **blocked kernels vs their scalar references**.
//!
//! The acceptance bar of the ops refactor: on D ≥ 64 panels, the blocked
//! `dot_many` must be ≥ 2× the scalar reference (independent accumulator
//! lanes break the serial FP dependence chain; the fused two-row panel
//! form halves query loads on top). This bench measures every primitive
//! pair on the dimensions the system actually runs (d, 4d, d²+1 for
//! d ∈ {8, 64}) and emits `BENCH_ops.json` with explicit speedup fields so
//! the claim is machine-checkable across PRs.
//!
//! Pure L3 — no artifacts. `cargo bench --bench ops_throughput`.

use kss::bench_harness::{
    print_speedup, print_table, scale, write_json_value, BenchRow, Bencher, Scale,
};
use kss::ops;
use kss::util::json::Value;
use kss::util::rng::Rng;

struct Pair {
    group: &'static str,
    dim: usize,
    scalar: BenchRow,
    blocked: BenchRow,
}

impl Pair {
    fn speedup(&self) -> f64 {
        self.scalar.mean_s / self.blocked.mean_s
    }
}

/// Which implementation the public `ops::*` entry points dispatch to in
/// this build — recorded in BENCH_ops.json so an `--features ops-scalar`
/// bisection run can never be mistaken for a blocked-kernel regression.
const OPS_IMPL: &str = if cfg!(feature = "ops-scalar") { "scalar-reference" } else { "blocked" };

fn main() {
    if cfg!(feature = "ops-scalar") {
        println!(
            "WARNING: built with --features ops-scalar — the public ops::* entry\n\
             points ARE the scalar references; every speedup below will read ~1.0x\n\
             and must not be compared against the acceptance bar."
        );
    }
    let dims: Vec<usize> = match scale() {
        Scale::Quick => vec![8, 32, 64, 257, 4097],
        Scale::Full => vec![8, 32, 64, 256, 257, 1024, 4097, 16384],
    };
    // panel rows ≈ a leaf block / HSM cluster / beam frontier
    let rows = 16usize;
    // repeat each kernel enough times per iteration that the timer
    // resolution never dominates a sub-microsecond dot
    let reps = 256usize;
    let bencher = Bencher { warmup_iters: 3, min_iters: 10, max_iters: 400, budget_s: 0.8 };

    let mut pairs: Vec<Pair> = Vec::new();
    let mut rng = Rng::new(0x0B5);
    for &dim in &dims {
        let a64: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let b64: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let a32: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b32: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let panel64: Vec<f64> = (0..dim * rows).map(|_| rng.normal()).collect();
        let panel32: Vec<f32> = (0..dim * rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let weights: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
        let mut out = vec![0.0f64; rows];
        let mut cum: Vec<f64> = Vec::with_capacity(dim);
        let items = Some(reps as f64);

        let scalar = bencher.run_with_items(&format!("dot scalar      D={dim:>6}"), items, || {
            for _ in 0..reps {
                std::hint::black_box(ops::reference::dot(
                    std::hint::black_box(&a64),
                    std::hint::black_box(&b64),
                ));
            }
        });
        let blocked = bencher.run_with_items(&format!("dot blocked     D={dim:>6}"), items, || {
            for _ in 0..reps {
                std::hint::black_box(ops::dot(std::hint::black_box(&a64), std::hint::black_box(&b64)));
            }
        });
        pairs.push(Pair { group: "dot", dim, scalar, blocked });

        let scalar = bencher.run_with_items(&format!("dot32 scalar    D={dim:>6}"), items, || {
            for _ in 0..reps {
                std::hint::black_box(ops::reference::dot32(
                    std::hint::black_box(&a32),
                    std::hint::black_box(&b32),
                ));
            }
        });
        let blocked = bencher.run_with_items(&format!("dot32 blocked   D={dim:>6}"), items, || {
            for _ in 0..reps {
                std::hint::black_box(ops::dot32(std::hint::black_box(&a32), std::hint::black_box(&b32)));
            }
        });
        pairs.push(Pair { group: "dot32", dim, scalar, blocked });

        let scalar = bencher.run_with_items(
            &format!("dot_many scalar  D={dim:>6} rows={rows}"),
            Some(rows as f64),
            || {
                ops::reference::dot_many(
                    std::hint::black_box(&a64),
                    std::hint::black_box(&panel64),
                    &mut out,
                );
                std::hint::black_box(&out);
            },
        );
        let blocked = bencher.run_with_items(
            &format!("dot_many blocked D={dim:>6} rows={rows}"),
            Some(rows as f64),
            || {
                ops::dot_many(std::hint::black_box(&a64), std::hint::black_box(&panel64), &mut out);
                std::hint::black_box(&out);
            },
        );
        pairs.push(Pair { group: "dot_many", dim, scalar, blocked });

        let scalar = bencher.run_with_items(
            &format!("dot_many_f32 scl D={dim:>6} rows={rows}"),
            Some(rows as f64),
            || {
                ops::reference::dot_many_f32(
                    std::hint::black_box(&a32),
                    std::hint::black_box(&panel32),
                    &mut out,
                );
                std::hint::black_box(&out);
            },
        );
        let blocked = bencher.run_with_items(
            &format!("dot_many_f32 blk D={dim:>6} rows={rows}"),
            Some(rows as f64),
            || {
                ops::dot_many_f32(std::hint::black_box(&a32), std::hint::black_box(&panel32), &mut out);
                std::hint::black_box(&out);
            },
        );
        pairs.push(Pair { group: "dot_many_f32", dim, scalar, blocked });

        // fill_cum has one legal order (sequential); benched for the record
        let row = bencher.run_with_items(&format!("fill_cum        D={dim:>6}"), Some(1.0), || {
            std::hint::black_box(ops::fill_cum(std::hint::black_box(&weights), &mut cum));
        });
        pairs.push(Pair { group: "fill_cum", dim, scalar: row.clone(), blocked: row });

        let mut y64 = b64.clone();
        let scalar = bencher.run_with_items(&format!("axpy scalar     D={dim:>6}"), items, || {
            for _ in 0..reps {
                ops::reference::axpy(&mut y64, 0.5, std::hint::black_box(&a64));
            }
            std::hint::black_box(&y64);
        });
        let mut y64 = b64.clone();
        let blocked = bencher.run_with_items(&format!("axpy blocked    D={dim:>6}"), items, || {
            for _ in 0..reps {
                ops::axpy(&mut y64, 0.5, std::hint::black_box(&a64));
            }
            std::hint::black_box(&y64);
        });
        pairs.push(Pair { group: "axpy", dim, scalar, blocked });
    }

    let rows_flat: Vec<BenchRow> = pairs
        .iter()
        .flat_map(|p| [p.scalar.clone(), p.blocked.clone()])
        .collect();
    print_table("ops primitives: scalar reference vs blocked", &rows_flat);
    for p in &pairs {
        if p.group != "fill_cum" {
            print_speedup(&format!("{} D={}", p.group, p.dim), &p.scalar, &p.blocked);
        }
    }
    println!("\n(acceptance target: blocked dot_many >= 2x scalar on D >= 64 panels)");
    let mut ok = true;
    for p in pairs.iter().filter(|p| p.group == "dot_many" && p.dim >= 64) {
        let s = p.speedup();
        println!("  dot_many D={:>6}: {:.2}x {}", p.dim, s, if s >= 2.0 { "OK" } else { "BELOW TARGET" });
        ok &= s >= 2.0;
    }
    if !ok {
        println!("  (target missed on this machine — see BENCH_ops.json for the record)");
    }

    let doc = Value::object(vec![
        ("bench", Value::str("ops")),
        (
            "scale",
            Value::str(match scale() {
                Scale::Quick => "quick",
                Scale::Full => "full",
            }),
        ),
        ("ops_impl", Value::str(OPS_IMPL)),
        ("panel_rows", Value::num(rows as f64)),
        (
            "series",
            Value::Array(
                pairs
                    .iter()
                    .map(|p| {
                        if p.group == "fill_cum" {
                            // one legal implementation (sequential prefix
                            // sum): no scalar-vs-blocked pair exists, so no
                            // speedup field — a flat 1.0 here would read as
                            // "blocked variant achieved no win" in a
                            // cross-PR diff
                            Value::object(vec![
                                ("op", Value::str(p.group)),
                                ("dim", Value::num(p.dim as f64)),
                                ("mean_s", Value::num(p.blocked.mean_s)),
                                ("single_impl", Value::Bool(true)),
                            ])
                        } else {
                            Value::object(vec![
                                ("op", Value::str(p.group)),
                                ("dim", Value::num(p.dim as f64)),
                                ("scalar_mean_s", Value::num(p.scalar.mean_s)),
                                ("blocked_mean_s", Value::num(p.blocked.mean_s)),
                                ("speedup", Value::num(p.speedup())),
                            ])
                        }
                    })
                    .collect(),
            ),
        ),
    ]);
    write_json_value("ops", &doc);
}
