//! Synthetic datasets standing in for the paper's corpora (DESIGN.md §3).
//!
//! * [`synptb`] — a Penn-Tree-Bank-style token stream from a ground-truth
//!   Markov (bigram) language with Zipf marginals: 10k-class vocabulary,
//!   skewed frequencies, context-dependent successors (so unigram < bigram <
//!   adaptive samplers, as in the paper's Figure 2 left).
//! * [`youtube`] — a latent-factor next-watch generator: users with
//!   preference clusters, Zipf item popularity, observable user features +
//!   the three previously watched videos (the paper's YouTube10k/100k shape).
//!
//! Both are deterministic functions of a seed. A [`Dataset`] yields
//! [`Batch`]es whose `data` tensors are already in the artifact input order,
//! plus the per-example metadata the samplers need (positives, LM context).

pub mod prefetch;
pub mod synptb;
pub mod youtube;

pub use prefetch::BatchPrefetcher;

use crate::runtime::Tensor;
use crate::sampler::CorpusStats;

/// One training/eval batch, ready for the runtime.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Model data inputs in artifact order (lm: tokens, targets;
    /// recsys: user, prev, pos) — exactly what train/eval ops expect after
    /// the params.
    pub data: Vec<Tensor>,
    /// Positive class per example (N = batch positions).
    pub pos: Vec<i32>,
    /// Previous-token context per example (LM only; the bigram sampler's
    /// conditioning variable).
    pub prev: Option<Vec<u32>>,
}

impl Batch {
    /// Number of training examples (softmax rows) in the batch.
    pub fn n_examples(&self) -> usize {
        self.pos.len()
    }
}

/// A dataset: batches + the corpus statistics frequency samplers train on.
pub trait Dataset: Send + Sync {
    fn name(&self) -> &str;
    fn n_classes(&self) -> usize;
    /// Batches for one epoch (deterministic given the epoch index).
    fn train_batches(&self, epoch: usize) -> Vec<Batch>;
    /// Held-out batches for full-softmax evaluation.
    fn eval_batches(&self) -> Vec<Batch>;
    /// Corpus statistics (unigram counts; bigram pair counts for LM).
    fn stats(&self) -> CorpusStats;
    /// True for language-model datasets (prev context available).
    fn is_lm(&self) -> bool;
}

#[cfg(test)]
mod tests {
    use super::synptb::SynPtb;
    use super::youtube::YouTube;
    use super::*;

    #[test]
    fn batches_have_consistent_shapes() {
        let ds = SynPtb::generate(200, 4, 5, 2_000, 400, 7);
        for b in ds.train_batches(0).iter().take(3).chain(ds.eval_batches().iter().take(2)) {
            assert_eq!(b.data.len(), 2);
            assert_eq!(b.pos.len(), 20);
            assert_eq!(b.prev.as_ref().unwrap().len(), 20);
        }
        let ds = YouTube::generate(300, 6, 1_000, 200, 16, 11);
        for b in ds.train_batches(0).iter().take(3) {
            assert_eq!(b.data.len(), 3);
            assert_eq!(b.pos.len(), 16);
            assert!(b.prev.is_none());
        }
    }
}
