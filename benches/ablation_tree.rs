//! §3.2.2 ablations — the tree's design knobs:
//!
//! * **leaf branching factor**: the paper suggests O(D/d)-sized leaves to
//!   cut memory from O(nD) to O(nd); this sweeps leaf sizes and reports
//!   draw cost, update cost and memory — showing D/d is a sane default.
//! * **multiple partial samples**: one descent returning a whole leaf
//!   (importance-weighted) vs m independent draws — faster per returned
//!   class, but correlated; we measure both the speed and the estimator
//!   quality (partition-function estimate variance).
//!
//! * **inverted multi-index frontier**: the midx sampler's bias/cost
//!   frontier against the tree, rff and two-pass engines at
//!   C ∈ {1e5, 1e6} (quick; full adds 1e7) — closed-form TV plus exact
//!   per-draw kernel-eval accounting, merged as a `midx` section into
//!   `BENCH_bias.json` with the C ≥ 1e6 acceptance flag.
//!
//! No artifacts needed. `cargo bench --bench ablation_tree`.

use kss::bench_harness::{print_speedup, print_table, scale, write_json_value, Bencher, BenchRow, Scale};
use kss::sampler::kernel::multi::PartialLeafSampler;
use kss::sampler::kernel::FeatureMap;
use kss::sampler::{
    row_rng, BatchSampleInput, KernelTreeSampler, MidxKernelSampler, PositiveRffMap,
    QuadraticMap, RffConfig, Sample, SampleInput, Sampler,
};
use kss::util::json::Value;
use kss::util::rng::Rng;
use kss::util::stats::tv_from_scores;
use kss::util::threadpool::default_threads;

fn main() {
    let (n, d) = match scale() {
        Scale::Quick => (10_000usize, 32usize),
        Scale::Full => (100_000, 64),
    };
    let m = 32usize;
    let dim = d * d + 1;
    let bencher = Bencher { warmup_iters: 1, min_iters: 5, max_iters: 60, budget_s: 1.0 };
    let mut rng = Rng::new(3);
    let mut w = vec![0.0f32; n * d];
    rng.fill_normal(&mut w, 0.3);
    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let input = SampleInput { h: Some(&h), ..Default::default() };

    // ---- leaf-size sweep ---------------------------------------------------
    println!("==== leaf branching factor sweep (n = {n}, d = {d}, D = {dim}) ====");
    println!("paper default: leaf = D/d = {}\n", dim / d);
    let mut rows: Vec<BenchRow> = Vec::new();
    for leaf in [1usize, d / 4, d, dim / d, 4 * dim / d, 16 * dim / d] {
        let leaf = leaf.max(1);
        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(leaf));
        tree.reset_embeddings(&w, n, d);
        let mem_mb = tree.node_count() as f64 * dim as f64 * 12.0 / 1e6; // f64 z + f32 shadow
        let mut out = Sample::default();
        let mut r = Rng::new(9);
        rows.push(bencher.run_with_items(
            &format!("leaf={leaf:>5} nodes={:>6} mem={mem_mb:>7.1}MB", tree.node_count()),
            Some(m as f64),
            || tree.sample(&input, m, &mut r, &mut out).unwrap(),
        ));
        let mut r = Rng::new(10);
        let mut w_new = vec![0.0f32; d];
        rows.push(bencher.run_with_items(
            &format!("  update leaf={leaf:>5}"),
            Some(1.0),
            || {
                r.fill_normal(&mut w_new, 0.3);
                let c = r.range(0, n);
                tree.update(c, &w_new);
            },
        ));
    }
    print_table("draw (m per example) and update costs by leaf size", &rows);

    // ---- multiple partial samples vs independent draws ---------------------
    println!("\n==== §3.2.2 multiple partial samples ====");
    let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree.reset_embeddings(&w, n, d);
    let leaf_size = tree.leaf_size();
    let partial = PartialLeafSampler::new(tree);
    let mut tree2 = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
    tree2.reset_embeddings(&w, n, d);

    let mut out = Sample::default();
    let mut r = Rng::new(21);
    let runs = (m / leaf_size).max(1); // same total classes as m draws
    let row_part = bencher.run_with_items(
        &format!("partial: {runs} descents x leaf {leaf_size}"),
        Some((runs * leaf_size) as f64),
        || partial.sample(&input, runs, &mut r, &mut out).unwrap(),
    );
    let mut r = Rng::new(21);
    let row_indep = bencher.run_with_items(
        &format!("independent: {m} draws"),
        Some(m as f64),
        || tree2.sample(&input, m, &mut r, &mut out).unwrap(),
    );
    print_table("classes returned per second", &[row_part, row_indep]);

    // estimator quality: Monte-Carlo variance of the importance-weighted
    // estimate of S = Σ_j f(o_j) (the quantity eq. 12 needs) under both
    // schemes, normalized per returned class. Partial sampling's classes
    // are correlated (whole leaves), so its per-class variance is higher —
    // exactly the trade the paper describes in §3.2.2.
    let score = |c: u32| -> f64 {
        let row = &w[c as usize * d..(c as usize + 1) * d];
        (row.iter().zip(&h).map(|(&a, &b)| (a * b) as f64).sum::<f64>()).exp()
    };
    let truth: f64 = (0..n as u32).map(score).sum();
    let trials = 1_000;
    let var_of = |use_partial: bool| -> f64 {
        let mut r = Rng::new(77);
        let mut s = Sample::default();
        let mut acc = 0.0;
        for _ in 0..trials {
            if use_partial {
                partial.sample(&input, runs, &mut r, &mut s).unwrap();
            } else {
                tree2.sample(&input, m, &mut r, &mut s).unwrap();
            }
            let draws = if use_partial { runs } else { m } as f64;
            let est: f64 =
                s.classes.iter().zip(&s.q).map(|(&c, &q)| score(c) / (draws * q)).sum();
            let rel = est / truth - 1.0;
            acc += rel * rel;
        }
        (acc / trials as f64).sqrt()
    };
    let v_ind = var_of(false);
    let v_part = var_of(true);
    println!("\npartition-estimate relative std over {trials} trials:");
    println!("  independent draws (m={m}):        {v_ind:.4}");
    println!("  partial leaves ({runs}x{leaf_size} classes):   {v_part:.4}");
    println!("\nboth are unbiased (eq. 12); partial sampling is cheaper per class");
    println!("but correlated, so it needs more classes for the same variance —");
    println!("the §3.2.2 trade-off. The trainer defaults to independent draws.");

    // ---- batched engine vs per-example loop --------------------------------
    println!("\n==== batch engine: sample_batch vs per-example loop ====");
    let batch_examples = 32usize;
    let threads = default_threads();
    let mut hs = vec![0.0f32; batch_examples * d];
    rng.fill_normal(&mut hs, 1.0);
    let base_input = BatchSampleInput {
        n: batch_examples,
        d,
        n_classes: n,
        h: Some(&hs),
        ..Default::default()
    };
    let batched_input = BatchSampleInput { threads, ..base_input };
    let mut outs: Vec<Sample> = (0..batch_examples).map(|_| Sample::with_capacity(m)).collect();
    let mut step = 0u64;
    let row_batched = bencher.run_with_items(
        &format!("batched ({batch_examples} ex × m={m}, {threads} thr)"),
        Some((batch_examples * m) as f64),
        || {
            step += 1;
            tree2.sample_batch(&batched_input, m, step, &mut outs).unwrap();
        },
    );
    let mut step = 0u64;
    let row_per_ex = bencher.run_with_items(
        &format!("per-example ({batch_examples} ex × m={m}, 1 thr)"),
        Some((batch_examples * m) as f64),
        || {
            step += 1;
            for (i, slot) in outs.iter_mut().enumerate() {
                let input = base_input.row(i);
                let mut r = row_rng(step, i);
                tree2.sample(&input, m, &mut r, slot).unwrap();
            }
        },
    );
    print_table(
        "batch engine (same per-row RNG streams, identical output)",
        &[row_batched.clone(), row_per_ex.clone()],
    );
    print_speedup("batched vs per-example", &row_per_ex, &row_batched);

    midx_frontier();
}

/// One engine's point on the midx bias/cost frontier at a catalog size C.
struct FrontierPoint {
    engine: &'static str,
    kernel: &'static str,
    n_classes: usize,
    feature_dim: usize,
    /// Kernel-eval work per returned draw, in scalar multiply-accumulates:
    /// a φ-aggregate touch (tree node, coarse cluster) costs `dim` MACs, a
    /// flat class kernel eval (leaf / refine) costs `d` — the unit that
    /// makes a d²+1-wide quadratic node touch and a d-wide leaf eval
    /// commensurable. Measured from real draws for tree/midx, closed-form
    /// for two-pass.
    macs_per_draw: f64,
    /// Closed-form TV(kernel proposal, exact softmax) over the queries —
    /// engines on the same kernel serve the identical exact distribution,
    /// so TV separates kernel *families* while the MAC column separates
    /// *engines*.
    avg_tv: f64,
    build_s: f64,
    /// Measured per-draw wall time (0 = analytic row, not timed).
    draw_s: f64,
}

/// Frontier panel geometry. Real production vocabularies are clustered —
/// that is the entire premise of coarse quantization — so the frontier
/// draws class embeddings from a FR_COMPONENTS-component mixture (unit
/// directions scaled to FR_CENTER_NORM, within-component std FR_SIGMA)
/// and queries near component centers. On an isotropic Gaussian panel no
/// coarse quantizer can beat a balanced tree: every cluster gets opened
/// and the refine degenerates to a full scan.
const FR_D: usize = 32;
const FR_COMPONENTS: usize = 32;
const FR_CENTER_NORM: f32 = 3.0;
const FR_SIGMA: f32 = 0.15;
const FR_EXAMPLES: usize = 4;
const FR_ALPHA: f64 = 100.0;
const FR_BUILD_SEED: u64 = 0x1DA8_5EED;

/// Mixture panel + FR_EXAMPLES queries, each near a component center.
fn mixture_panel(c: usize, rng: &mut Rng) -> (Vec<f32>, Vec<Vec<f32>>) {
    let d = FR_D;
    let mut centers = vec![0.0f32; FR_COMPONENTS * d];
    rng.fill_normal(&mut centers, 1.0);
    for g in 0..FR_COMPONENTS {
        let row = &mut centers[g * d..(g + 1) * d];
        let norm = row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32;
        for x in row.iter_mut() {
            *x *= FR_CENTER_NORM / norm.max(1e-6);
        }
    }
    let mut emb = vec![0.0f32; c * d];
    rng.fill_normal(&mut emb, FR_SIGMA);
    for class in 0..c {
        let center = &centers[(class % FR_COMPONENTS) * d..(class % FR_COMPONENTS) * d + d];
        for (slot, &cx) in emb[class * d..(class + 1) * d].iter_mut().zip(center) {
            *slot += cx;
        }
    }
    let mut hs = Vec::with_capacity(FR_EXAMPLES);
    for e in 0..FR_EXAMPLES {
        let center = &centers[(e % FR_COMPONENTS) * d..(e % FR_COMPONENTS) * d + d];
        let mut h = vec![0.0f32; d];
        rng.fill_normal(&mut h, 0.1);
        for (slot, &cx) in h.iter_mut().zip(center) {
            *slot += cx;
        }
        hs.push(h);
    }
    (emb, hs)
}

/// Closed-form TV between the kernel proposal and the exact softmax
/// target, averaged over the queries. Exact (no Monte-Carlo noise), and
/// by construction identical for every engine serving the same kernel.
fn frontier_tv<M: FeatureMap>(map: &M, emb: &[f32], c: usize, hs: &[Vec<f32>]) -> f64 {
    let mut logits = vec![0.0f64; c];
    let mut target = vec![0.0f64; c];
    let mut ks = vec![0.0f64; c];
    let mut acc = 0.0;
    for h in hs {
        kss::ops::dot_many_f32(h, emb, &mut logits);
        let (_, z) = kss::ops::max_shift_exp(&logits, &mut target);
        for t in target.iter_mut() {
            *t /= z;
        }
        map.kernel_many(h, emb, &mut ks);
        acc += tv_from_scores(&ks, &target);
    }
    acc / hs.len() as f64
}

/// Build a kernel tree, draw `m` per query, account MACs per draw the way
/// the descent actually spends them: φ(h) once per example, two node
/// aggregate dots per level per draw, one flat leaf scan per draw.
fn frontier_tree<M: FeatureMap + Clone>(
    map: M,
    emb: &[f32],
    c: usize,
    leaf: usize,
    m: usize,
    hs: &[Vec<f32>],
) -> (f64, f64, f64, usize) {
    let dim = map.dim() as f64;
    let t0 = std::time::Instant::now();
    let mut tree = KernelTreeSampler::new(map, c, Some(leaf));
    tree.reset_embeddings(emb, c, FR_D);
    let build_s = t0.elapsed().as_secs_f64();
    let depth = tree.depth();
    let mut out = Sample::default();
    let mut rng = Rng::new(0xF407);
    let mut macs = 0.0f64;
    let t0 = std::time::Instant::now();
    for h in hs {
        let input = SampleInput { h: Some(h), ..Default::default() };
        tree.sample(&input, m, &mut rng, &mut out).unwrap();
        macs += dim;
        for &class in &out.classes {
            macs += 2.0 * depth as f64 * dim + tree.leaf_range_of(class).len() as f64 * FR_D as f64;
        }
    }
    let draws = (hs.len() * m) as f64;
    let draw_s = t0.elapsed().as_secs_f64() / draws;
    (macs / draws, build_s, draw_s, depth)
}

/// Build a midx sampler, draw `m` per query, account MACs: φ(h) plus the
/// K-cluster coarse CDF once per example, then one flat cluster scan per
/// *distinct* drawn cluster (the refine memo — the engine's whole edge).
fn frontier_midx<M: FeatureMap + Clone>(
    map: M,
    emb: &[f32],
    c: usize,
    lloyd_iters: usize,
    m: usize,
    hs: &[Vec<f32>],
) -> (f64, f64, f64, usize) {
    let dim = map.dim() as f64;
    let t0 = std::time::Instant::now();
    let mut midx = MidxKernelSampler::with_config(map, c, None, lloyd_iters, FR_BUILD_SEED);
    Sampler::reset_embeddings(&mut midx, emb, c, FR_D);
    let build_s = t0.elapsed().as_secs_f64();
    let k = midx.clusters();
    let mut cluster_len = vec![0u64; k];
    for class in 0..c {
        cluster_len[midx.index().cluster_of(class)] += 1;
    }
    let mut out = Sample::default();
    let mut rng = Rng::new(0xF407);
    let mut macs = 0.0f64;
    let t0 = std::time::Instant::now();
    for h in hs {
        let input = SampleInput { h: Some(h), ..Default::default() };
        midx.sample(&input, m, &mut rng, &mut out).unwrap();
        macs += dim + k as f64 * dim;
        let mut seen = vec![false; k];
        for &class in &out.classes {
            let kc = midx.index().cluster_of(class as usize);
            if !seen[kc] {
                seen[kc] = true;
                macs += cluster_len[kc] as f64 * FR_D as f64;
            }
        }
    }
    let draws = (hs.len() * m) as f64;
    let draw_s = t0.elapsed().as_secs_f64() / draws;
    (macs / draws, build_s, draw_s, k)
}

/// Inverted multi-index frontier: midx vs tree vs rff vs two-pass at
/// C ∈ {1e5, 1e6} (quick; full adds 1e7). Engines are built, measured
/// and dropped one at a time so peak memory stays one-engine-deep.
fn midx_frontier() {
    let sizes: &[usize] = match scale() {
        Scale::Quick => &[100_000, 1_000_000],
        Scale::Full => &[100_000, 1_000_000, 10_000_000],
    };
    println!("\n==== inverted multi-index frontier (d = {FR_D}, mixture G = {FR_COMPONENTS}) ====");
    let mut points: Vec<FrontierPoint> = Vec::new();
    for &c in sizes {
        // leaf grows with C to keep the tree's z-stat arena in memory
        // (quadratic dim = d²+1 = 1025: leaf 64 at 1e7 would be 2.5 GB);
        // m grows with C like production negative-sample counts do, and
        // the 1e7 k-means settles for the seeding assignment alone (one
        // Lloyd pass over 1e7×K=3163 is ~1e12 MACs of build time).
        let (leaf, m, lloyd_iters) = match c {
            100_000 => (64usize, 512usize, 1usize),
            1_000_000 => (128, 512, 1),
            _ => (256, 8192, 0),
        };
        let mut rng = Rng::new(0x1D11 ^ c as u64);
        let (emb, hs) = mixture_panel(c, &mut rng);
        let quad = QuadraticMap::new(FR_D, FR_ALPHA);
        let rff = PositiveRffMap::new(RffConfig::new(FR_D, 0x2FF));
        let quad_dim = quad.dim();
        let rff_dim = rff.dim();
        let quad_tv = frontier_tv(&quad, &emb, c, &hs);
        let rff_tv = frontier_tv(&rff, &emb, c, &hs);

        let (t_macs, t_build, t_draw, depth) = frontier_tree(quad.clone(), &emb, c, leaf, m, &hs);
        points.push(FrontierPoint {
            engine: "tree",
            kernel: "quadratic",
            n_classes: c,
            feature_dim: quad_dim,
            macs_per_draw: t_macs,
            avg_tv: quad_tv,
            build_s: t_build,
            draw_s: t_draw,
        });
        let (x_macs, x_build, x_draw, k) = frontier_midx(quad.clone(), &emb, c, lloyd_iters, m, &hs);
        points.push(FrontierPoint {
            engine: "midx",
            kernel: "quadratic",
            n_classes: c,
            feature_dim: quad_dim,
            macs_per_draw: x_macs,
            avg_tv: quad_tv,
            build_s: x_build,
            draw_s: x_draw,
        });
        let (rt_macs, rt_build, rt_draw, _) = frontier_tree(rff.clone(), &emb, c, leaf, m, &hs);
        points.push(FrontierPoint {
            engine: "tree",
            kernel: "rff",
            n_classes: c,
            feature_dim: rff_dim,
            macs_per_draw: rt_macs,
            avg_tv: rff_tv,
            build_s: rt_build,
            draw_s: rt_draw,
        });
        let (rx_macs, rx_build, rx_draw, _) = frontier_midx(rff.clone(), &emb, c, lloyd_iters, m, &hs);
        points.push(FrontierPoint {
            engine: "midx",
            kernel: "rff",
            n_classes: c,
            feature_dim: rff_dim,
            macs_per_draw: rx_macs,
            avg_tv: rff_tv,
            build_s: rx_build,
            draw_s: rx_draw,
        });
        // two-pass closed form at batch B: P = ⌈B·m/pool_factor⌉ pooled
        // descents plus a P-candidate d-dim rescore per row, amortized
        // over B·m draws (see two_pass.rs; pool_factor 4 is the default)
        let (b, pool_factor) = (32.0f64, 4.0f64);
        let pool = (b * m as f64 / pool_factor).ceil();
        let tp_macs = (quad_dim as f64
            + pool * (2.0 * depth as f64 * quad_dim as f64 + leaf as f64 * FR_D as f64)
            + b * pool * FR_D as f64)
            / (b * m as f64);
        points.push(FrontierPoint {
            engine: "two-pass",
            kernel: "quadratic",
            n_classes: c,
            feature_dim: quad_dim,
            macs_per_draw: tp_macs,
            avg_tv: quad_tv,
            build_s: 0.0,
            draw_s: 0.0,
        });
        println!(
            "C={c:>9} K={k:>5} m={m:>5} leaf={leaf:>4}  MACs/draw: tree {t_macs:>9.0}  \
             midx {x_macs:>9.0}  2pass {tp_macs:>9.0}  rff-tree {rt_macs:>9.0}  \
             rff-midx {rx_macs:>9.0}  TV quad {quad_tv:.4} rff {rff_tv:.4}"
        );
    }

    // acceptance: at every measured C ≥ 1e6 the midx engine must spend
    // less kernel-eval work per draw than the tree at equal-or-lower TV
    // (equal by construction — same kernel ⇒ identical exact proposal)
    let accepted = sizes.iter().filter(|&&c| c >= 1_000_000).all(|&c| {
        let find = |engine: &str| {
            points
                .iter()
                .find(|p| p.engine == engine && p.kernel == "quadratic" && p.n_classes == c)
                .expect("frontier point recorded")
        };
        let (t, x) = (find("tree"), find("midx"));
        x.macs_per_draw < t.macs_per_draw && x.avg_tv <= t.avg_tv + 1e-12
    });
    println!("acceptance (midx beats tree on kernel-eval MACs/draw at C ≥ 1e6): {accepted}");

    // merge the frontier into BENCH_bias.json (ablation_rff_dim writes the
    // base document; CI orders this bench after it)
    let midx_doc = Value::object(vec![
        (
            "scale",
            Value::str(match scale() {
                Scale::Quick => "quick",
                Scale::Full => "full",
            }),
        ),
        ("d", Value::num(FR_D as f64)),
        ("mixture_components", Value::num(FR_COMPONENTS as f64)),
        ("acceptance_midx_beats_tree_at_1e6", Value::Bool(accepted)),
        (
            "frontier",
            Value::Array(
                points
                    .iter()
                    .map(|p| {
                        Value::object(vec![
                            ("engine", Value::str(p.engine)),
                            ("kernel", Value::str(p.kernel)),
                            ("n_classes", Value::num(p.n_classes as f64)),
                            ("feature_dim", Value::num(p.feature_dim as f64)),
                            ("kernel_eval_macs_per_draw", Value::num(p.macs_per_draw)),
                            ("avg_tv_vs_softmax", Value::num(p.avg_tv)),
                            ("build_seconds", Value::num(p.build_s)),
                            ("draw_seconds", Value::num(p.draw_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let dir = std::env::var("KSS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join("BENCH_bias.json");
    let merged =
        match std::fs::read_to_string(&path).ok().and_then(|t| kss::util::json::parse(&t).ok()) {
            Some(Value::Object(pairs)) => {
                let mut pairs: Vec<(String, Value)> =
                    pairs.into_iter().filter(|(key, _)| key != "midx").collect();
                pairs.push(("midx".to_string(), midx_doc));
                Value::Object(pairs)
            }
            // no base document yet (bench ran standalone): self-contained
            _ => Value::object(vec![("bench", Value::str("bias")), ("midx", midx_doc)]),
        };
    write_json_value("bias", &merged);
}
