//! Bigram sampling, `q(i | prev) ∝ count(prev, i)` with unigram back-off —
//! the strongest static NLP baseline in the paper's Penn-Tree-Bank figures
//! (and exactly the kind of *context-dependent but model-independent*
//! distribution §2.4 argues is still not good enough: it cannot follow the
//! model's parameters as they move).
//!
//! q(i | prev) = λ · bigram(i | prev) + (1 − λ) · unigram(i)
//!
//! Sampling is O(1): flip λ, then draw from the per-context alias table (or
//! the unigram table). The reported q is the exact mixture probability, so
//! the eq. (2) correction stays unbiased in the m → ∞ limit.
//!
//! q-positivity: a class drawn through the bigram arm has a positive bigram
//! probability, and a class drawn through the unigram arm has a positive
//! (add-one smoothed) unigram probability with weight (1 − λ) — either way
//! the reported mixture q is strictly positive for every drawable class.

use super::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::{AliasTable, Rng};
use anyhow::{Context, Result};
use std::collections::HashMap;

struct ContextTable {
    alias: AliasTable,
    /// class -> index in the alias table (sparse successor set).
    classes: Vec<u32>,
    prob_by_class: HashMap<u32, f64>,
}

/// Mixture-of-bigram-and-unigram sampler.
pub struct BigramSampler {
    unigram: AliasTable,
    contexts: Vec<Option<ContextTable>>,
    lambda: f64,
}

impl BigramSampler {
    /// `pair_counts[prev]` lists (next, count) pairs observed in the corpus.
    pub fn new(class_counts: &[u64], pair_counts: &[Vec<(u32, u64)>], lambda: f64) -> Result<BigramSampler> {
        assert!((0.0..=1.0).contains(&lambda));
        let weights: Vec<f64> = class_counts.iter().map(|&c| c as f64 + 1.0).collect();
        let unigram = AliasTable::new(&weights).context("degenerate unigram counts")?;
        let mut contexts = Vec::with_capacity(pair_counts.len());
        for pairs in pair_counts {
            if pairs.is_empty() {
                contexts.push(None);
                continue;
            }
            let ws: Vec<f64> = pairs.iter().map(|&(_, c)| c as f64).collect();
            let alias = AliasTable::new(&ws).context("degenerate bigram row")?;
            let classes: Vec<u32> = pairs.iter().map(|&(c, _)| c).collect();
            let prob_by_class =
                classes.iter().enumerate().map(|(j, &c)| (c, alias.prob_of(j))).collect();
            contexts.push(Some(ContextTable { alias, classes, prob_by_class }));
        }
        Ok(BigramSampler { unigram, contexts, lambda })
    }

    fn mixture_prob(&self, prev: u32, class: u32) -> f64 {
        let uni = self.unigram.prob_of(class as usize);
        match self.contexts.get(prev as usize).and_then(|c| c.as_ref()) {
            None => uni, // no bigram row: pure unigram
            Some(ctx) => {
                let bi = ctx.prob_by_class.get(&class).copied().unwrap_or(0.0);
                self.lambda * bi + (1.0 - self.lambda) * uni
            }
        }
    }
}

impl Sampler for BigramSampler {
    fn name(&self) -> &str {
        "bigram"
    }

    fn needs(&self) -> Needs {
        Needs { prev: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let prev = input.prev.ok_or_else(|| anyhow::anyhow!("bigram sampler needs prev token"))?;
        out.clear();
        let ctx = self.contexts.get(prev as usize).and_then(|c| c.as_ref());
        for _ in 0..m {
            let class = match ctx {
                Some(ctx) if rng.bool(self.lambda) => ctx.classes[ctx.alias.sample(rng)],
                _ => self.unigram.sample(rng) as u32,
            };
            out.push(class, self.mixture_prob(prev, class));
        }
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        input.prev.map(|p| self.mixture_prob(p, class))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::test_util::empirical_tv;

    fn sampler() -> BigramSampler {
        // 4 classes; context 0 strongly prefers class 2; context 1 unseen.
        let class_counts = vec![9u64, 19, 4, 3]; // +1 => 10,20,5,4
        let pairs = vec![vec![(2u32, 8u64), (0, 2)], vec![]];
        BigramSampler::new(&class_counts, &pairs, 0.8).unwrap()
    }

    #[test]
    fn mixture_probabilities_sum_to_one() {
        let s = sampler();
        for prev in [0u32, 1] {
            let total: f64 = (0..4)
                .map(|c| s.prob(&SampleInput { prev: Some(prev), ..Default::default() }, c).unwrap())
                .sum();
            assert!((total - 1.0).abs() < 1e-12, "prev={prev}: {total}");
        }
    }

    #[test]
    fn context_shifts_distribution() {
        let s = sampler();
        let in0 = SampleInput { prev: Some(0), ..Default::default() };
        let in1 = SampleInput { prev: Some(1), ..Default::default() };
        // class 2 boosted after context 0: λ·0.8 + (1-λ)·5/39
        let q2_ctx0 = s.prob(&in0, 2).unwrap();
        let q2_ctx1 = s.prob(&in1, 2).unwrap();
        assert!(q2_ctx0 > 4.0 * q2_ctx1, "{q2_ctx0} vs {q2_ctx1}");
        // unseen context falls back to unigram exactly
        assert!((q2_ctx1 - 5.0 / 39.0).abs() < 1e-12);
    }

    #[test]
    fn empirical_matches_mixture() {
        let s = sampler();
        let in0 = SampleInput { prev: Some(0), ..Default::default() };
        let expected: Vec<f64> = (0..4).map(|c| s.prob(&in0, c).unwrap()).collect();
        let tv = empirical_tv(&s, &in0, &expected, 200_000, 11);
        assert!(tv < 0.02, "tv {tv}");
    }

    #[test]
    fn missing_prev_is_error() {
        let s = sampler();
        let mut rng = Rng::new(0);
        let mut out = Sample::default();
        assert!(s.sample(&SampleInput::default(), 4, &mut rng, &mut out).is_err());
    }
}
