//! Online serving: snapshot-isolated concurrent sampling over the kernel
//! tree — the layer that turns the training-time sampler into a query
//! service (ROADMAP: "heavy traffic from millions of users").
//!
//! The kernel tree is a great *training* structure but was single-writer:
//! nothing could draw while `update_many` swept the arena. This subsystem
//! makes the same index serve concurrent traffic:
//!
//! * [`snapshot`] — epoch snapshots: immutable `Arc`'d tree generations
//!   behind an atomic publish point ([`SnapshotStore`]); readers are
//!   wait-free in steady state, and the [`TreePublisher`] double-buffers
//!   arenas (reclaim + replay, no rebuild, no steady-state copy).
//! * [`shard`] — [`ShardedKernelSampler`]: the class space split into S
//!   sub-trees behind a router that draws shards from the root-mass CDF
//!   and rescales per-shard q so the merged proposal distribution is
//!   exactly the unsharded eq. (8) one (property-tested). Shards update in
//!   parallel and publish independently.
//! * [`batcher`] — [`MicroBatcher`]: a bounded queue that coalesces
//!   concurrent single-row requests into batched draws under a latency
//!   deadline, preserving per-request determinism via `row_rng` streams.
//! * [`topk`] — beam retrieval: approximate top-k classes by kernel score
//!   over the same arenas (inference-style recommendation queries),
//!   sharing the draw path's zero-mass guards.
//! * [`service`] — [`SamplingService`]: shard snapshot stores + batcher +
//!   worker pool behind one façade, and the [`ShardSet`] writer bundle.
//! * [`reader_sampler`] — [`SnapshotSampler`]: the snapshot stores turned
//!   back into a training-side [`crate::sampler::Sampler`]. The pipelined
//!   trainer draws its negatives through this adapter, so training and
//!   serving share one tree, one update sweep and one publish point.
//!
//! The `kss serve` subcommand drives the whole stack with the closed-loop
//! load generator below ([`run_load_test`]); `benches/serve_throughput.rs`
//! measures reader scaling and publish stalls.

pub mod batcher;
pub mod reader_sampler;
pub mod service;
pub mod shard;
pub mod snapshot;
pub mod topk;

pub use batcher::{BatcherConfig, MicroBatcher, SampleResponse, ServeError};
pub use reader_sampler::SnapshotSampler;
pub use service::{SamplingService, ServiceConfig, ServiceObs, ShardPublisher, ShardSet};
pub use shard::{
    draw_from_shards, shard_of_class, shard_offsets, split_updates_by_shard, ShardedKernelSampler,
};
pub use snapshot::{
    PublishReport, PublishStats, SnapshotReader, SnapshotStore, TreePublisher, TreeSnapshot,
};
pub use topk::{merge_shard_topk, topk_over_snapshots, Hit, TopKConfig};

use crate::obs::MetricsRegistry;
use crate::sampler::kernel::tree::KernelTreeSampler;
use crate::sampler::kernel::{FeatureMap, QuadraticMap};
use crate::sampler::rff::{PositiveRffMap, RffConfig};
use crate::sampler::{Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use crate::util::stats::Samples;
use crate::vocab::{CompactionPolicy, VocabPublisher, VocabSnapshotSampler};
use std::time::{Duration, Instant};

/// Which kernel family the serve stack hosts. The whole serving layer
/// (publishers, shards, workers, retrieval) is generic over [`FeatureMap`];
/// this enum is only the CLI-facing dispatch point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeKernel {
    /// The paper's `αo² + 1` quadratic kernel (eq. 10).
    Quadratic,
    /// Positive random features approximating `exp(o)` (the rff family).
    Rff,
}

impl ServeKernel {
    /// Parse a `--kernel` flag value.
    pub fn parse(name: &str) -> anyhow::Result<ServeKernel> {
        match name {
            "quadratic" => Ok(ServeKernel::Quadratic),
            "rff" => Ok(ServeKernel::Rff),
            other => anyhow::bail!("unknown serve kernel '{other}' (known: quadratic, rff)"),
        }
    }
}

/// Closed-loop load-test parameters (the `kss serve` subcommand).
#[derive(Clone, Debug)]
pub struct LoadGenConfig {
    /// Catalog size (classes) and embedding dim of the synthetic index.
    pub n_classes: usize,
    pub d: usize,
    /// Kernel family the index is built on.
    pub kernel: ServeKernel,
    /// Kernel α (eq. 10; quadratic only).
    pub alpha: f64,
    /// RFF feature dimension D (0 = the registry default `4·d`; rff only).
    pub rff_dim: usize,
    pub shards: usize,
    pub workers: usize,
    /// Closed-loop client threads; each issues `requests` sequentially.
    pub clients: usize,
    pub requests: usize,
    /// Negatives per request.
    pub m: usize,
    /// Top-k retrieval calls interleaved per client (every 16th request).
    pub topk: TopKConfig,
    pub batcher: BatcherConfig,
    /// Writer cadence: classes updated + published per writer iteration
    /// (0 disables the writer).
    pub updates_per_publish: usize,
    /// End-to-end latency budget a request must meet (queue + execute).
    pub deadline: Duration,
    pub seed: u64,
    /// Where to write the Prometheus-style metrics exposition on exit
    /// (`--metrics-path`; `None` keeps it in [`LoadReport::metrics_text`]
    /// only).
    pub metrics_path: Option<std::path::PathBuf>,
    /// Route worker draws through the inverted multi-index with this many
    /// clusters (`--midx-clusters`; 0 = per-row tree descents; requires
    /// `shards = 1`).
    pub midx_clusters: usize,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            n_classes: 10_000,
            d: 16,
            kernel: ServeKernel::Quadratic,
            alpha: 100.0,
            rff_dim: 0,
            shards: 4,
            workers: 2,
            clients: 4,
            requests: 1_000,
            m: 8,
            topk: TopKConfig::default(),
            batcher: BatcherConfig::default(),
            updates_per_publish: 32,
            deadline: Duration::from_millis(20),
            seed: 42,
            metrics_path: None,
            midx_clusters: 0,
        }
    }
}

/// What the load test observed.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub completed: u64,
    pub rejected: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// End-to-end request latency (submit → response received), seconds.
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,
    /// Fraction of completed requests over the deadline.
    pub deadline_miss_rate: f64,
    /// Publishes performed while the load ran, and their costs.
    pub publishes: u64,
    pub publish_stats: PublishStats,
    pub publish_build_p95_s: f64,
    /// Worst swap-lock hold time — the only interval a reader can contend
    /// with a publish.
    pub publish_swap_max_s: f64,
    pub topk_calls: u64,
    /// Prometheus-style exposition of every serve-stack metric at exit
    /// (batcher, service, publisher and sampler cells) — what
    /// `--metrics-path` writes to disk.
    pub metrics_text: String,
}

/// Drive a synthetic sharded index with closed-loop clients while a writer
/// continuously updates and publishes. Returns the observed latency /
/// throughput / publish profile; the caller (CLI, CI smoke job) decides
/// pass/fail against its own thresholds. Dispatches on
/// [`LoadGenConfig::kernel`] into the kernel-generic loop — the serving
/// stack itself never mentions a concrete map.
pub fn run_load_test(cfg: &LoadGenConfig) -> LoadReport {
    match cfg.kernel {
        ServeKernel::Quadratic => {
            run_load_test_with(QuadraticMap::new(cfg.d, cfg.alpha), cfg)
        }
        ServeKernel::Rff => {
            let mut rff = RffConfig::new(cfg.d, cfg.seed ^ 0x2FF_5EED);
            if cfg.rff_dim > 0 {
                rff = rff.with_dim(cfg.rff_dim);
            }
            run_load_test_with(PositiveRffMap::new(rff), cfg)
        }
    }
}

/// The kernel-generic closed loop behind [`run_load_test`].
pub fn run_load_test_with<M: FeatureMap + Clone + 'static>(
    map: M,
    cfg: &LoadGenConfig,
) -> LoadReport {
    let mut rng = Rng::new(cfg.seed);
    let mut emb = vec![0.0f32; cfg.n_classes * cfg.d];
    rng.fill_normal(&mut emb, 0.3);
    let mut set = ShardSet::new(map, cfg.n_classes, cfg.shards, None, Some(&emb));
    let service_cfg = ServiceConfig {
        workers: cfg.workers,
        batcher: cfg.batcher,
        seed: cfg.seed ^ 0x5E17E,
        topk: cfg.topk,
        max_m: cfg.m.max(4096),
        request_timeout: Duration::from_secs(30),
        midx_clusters: cfg.midx_clusters,
    };
    let service = SamplingService::start(set.stores(), set.offsets().to_vec(), service_cfg);
    // one registry over the whole stack: request path (batcher + service),
    // publish path (per-shard publishers) and the sampler cells behind them
    let registry = MetricsRegistry::new();
    service.register_metrics(&registry);
    set.register_metrics(&registry);

    let stop_writer = std::sync::atomic::AtomicBool::new(false);
    let mut latencies = Samples::new();
    let mut completed = 0u64;
    let mut misses = 0u64;
    let mut topk_calls = 0u64;
    let mut build_times = Samples::new();
    let mut swap_max = 0.0f64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        // closed-loop clients
        let mut handles = Vec::new();
        for client in 0..cfg.clients as u64 {
            let service = &service;
            let (d, m, requests, deadline, topk) =
                (cfg.d, cfg.m, cfg.requests, cfg.deadline, cfg.topk);
            let seed = cfg.seed;
            handles.push(scope.spawn(move || {
                let mut crng = Rng::new(seed ^ (0xC11E + client));
                let mut lats = Vec::with_capacity(requests);
                let mut done = 0u64;
                let mut missed = 0u64;
                let mut topks = 0u64;
                for i in 0..requests {
                    let h: Vec<f32> = (0..d).map(|_| crng.normal_f32(0.0, 1.0)).collect();
                    if topk.k > 0 && i % 16 == 15 {
                        let hits = service.topk(&h).expect("well-formed retrieval rejected");
                        assert!(!hits.is_empty(), "retrieval returned nothing");
                        topks += 1;
                        continue;
                    }
                    let t = Instant::now();
                    match service.sample_blocking(h, m) {
                        Ok(resp) => {
                            let lat = t.elapsed();
                            assert_eq!(resp.sample.classes.len(), m);
                            lats.push(lat.as_secs_f64());
                            done += 1;
                            if lat > deadline {
                                missed += 1;
                            }
                        }
                        Err(ServeError::Overloaded) => {
                            // shed: back off a little, closed loop retries
                            // implicitly on the next iteration
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(ServeError::ShuttingDown) => break,
                        // the load generator only builds well-formed
                        // requests; a validation reject or a request
                        // timeout means the stack is broken — fail loud
                        // (this is the CI smoke gate)
                        Err(e) => panic!("request failed unexpectedly: {e}"),
                    }
                }
                (lats, done, missed, topks)
            }));
        }
        // writer: update random classes, publish per shard, until clients
        // finish
        let writer = (cfg.updates_per_publish > 0).then(|| {
            let stop_writer = &stop_writer;
            let set = &mut set;
            let k = cfg.updates_per_publish;
            let seed = cfg.seed;
            scope.spawn(move || {
                let mut wrng = Rng::new(seed ^ 0x3217E4);
                let mut builds = Samples::new();
                let mut swap_worst = 0.0f64;
                while !stop_writer.load(std::sync::atomic::Ordering::Relaxed) {
                    for report in set.publish_random_batch(&mut wrng, k) {
                        builds.push(report.build_s);
                        swap_worst = swap_worst.max(report.swap_s);
                    }
                    std::thread::sleep(Duration::from_micros(500));
                }
                (builds, swap_worst)
            })
        });
        for handle in handles {
            let (lats, done, missed, topks) = handle.join().expect("client panicked");
            for l in lats {
                latencies.push(l);
            }
            completed += done;
            misses += missed;
            topk_calls += topks;
        }
        stop_writer.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(w) = writer {
            let (builds, swap_worst) = w.join().expect("writer panicked");
            build_times = builds;
            swap_max = swap_worst;
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let publish_stats = set.stats();
    let metrics_text = registry.snapshot().render_prometheus();
    if let Some(path) = &cfg.metrics_path {
        if let Err(e) = std::fs::write(path, &metrics_text) {
            eprintln!("warning: could not write metrics exposition to {}: {e}", path.display());
        }
    }
    let lat = latencies.percentiles(&[50.0, 95.0, 99.0, 100.0]);
    let report = LoadReport {
        completed,
        rejected: service.rejected(),
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        latency_p50_s: lat[0],
        latency_p95_s: lat[1],
        latency_p99_s: lat[2],
        latency_max_s: lat[3],
        deadline_miss_rate: if completed == 0 { 1.0 } else { misses as f64 / completed as f64 },
        publishes: publish_stats.publishes,
        publish_stats,
        publish_build_p95_s: build_times.p95(),
        publish_swap_max_s: swap_max,
        topk_calls,
        metrics_text,
    };
    service.shutdown();
    report
}

/// Parameters of the `--scenario churn` closed loop: reader threads sample
/// from composite streaming-vocabulary snapshots while a writer inserts,
/// retires and re-embeds classes at a configurable cadence
/// (`crate::vocab`). The readers assert eq. (2) q-positivity and
/// generation-coherent liveness on **every** draw — the run panics on a
/// violation, which is the CI smoke gate.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    /// Initial catalog size (classes) and embedding dim.
    pub n_classes: usize,
    pub d: usize,
    /// Kernel family the arena is built on.
    pub kernel: ServeKernel,
    /// Kernel α (quadratic only).
    pub alpha: f64,
    /// RFF feature dimension D (0 = registry default `4·d`; rff only).
    pub rff_dim: usize,
    /// Reader threads; each issues `draws` sequential sampling requests.
    pub clients: usize,
    pub draws: usize,
    /// Negatives per request.
    pub m: usize,
    /// One class inserted every `insert_every` writer rounds (0 disables).
    pub insert_every: usize,
    /// One live class retired every `retire_every` writer rounds (0
    /// disables).
    pub retire_every: usize,
    /// Live classes re-embedded per writer round (trainer-style churn;
    /// 0 disables).
    pub update_batch: usize,
    /// When the publisher folds the memtable into the arena.
    pub policy: CompactionPolicy,
    /// Per-draw latency budget readers measure miss-rate against.
    pub deadline: Duration,
    pub seed: u64,
    /// Where to write the Prometheus exposition on exit (`--metrics-path`).
    pub metrics_path: Option<std::path::PathBuf>,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            n_classes: 2_000,
            d: 8,
            kernel: ServeKernel::Quadratic,
            alpha: 100.0,
            rff_dim: 0,
            clients: 3,
            draws: 400,
            m: 8,
            insert_every: 1,
            retire_every: 2,
            update_batch: 16,
            policy: CompactionPolicy::default(),
            deadline: Duration::from_millis(20),
            seed: 42,
            metrics_path: None,
        }
    }
}

/// What the churn scenario observed.
#[derive(Clone, Debug)]
pub struct ChurnReport {
    /// Sampling requests completed (every one passed the q/liveness
    /// assertions — violations panic the run).
    pub draws: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_max_s: f64,
    /// Fraction of draws over the deadline.
    pub deadline_miss_rate: f64,
    /// Classes inserted / retired while the load ran.
    pub inserts: u64,
    pub retires: u64,
    /// Memtable→arena folds (policy-driven plus the end-of-run drain).
    pub compactions: u64,
    /// Live classes after the final drain fold.
    pub live_classes: usize,
    /// Draw routing split across the tiers.
    pub tier_arena: u64,
    pub tier_memtable: u64,
    /// Prometheus exposition at exit (vocab + publish series) — what
    /// `--metrics-path` writes to disk.
    pub metrics_text: String,
}

/// Drive the streaming vocabulary under live traffic (the `--scenario
/// churn` entry point). Dispatches on [`ChurnConfig::kernel`] into the
/// kernel-generic loop.
pub fn run_churn_test(cfg: &ChurnConfig) -> ChurnReport {
    match cfg.kernel {
        ServeKernel::Quadratic => {
            run_churn_test_with(QuadraticMap::new(cfg.d, cfg.alpha), cfg)
        }
        ServeKernel::Rff => {
            let mut rff = RffConfig::new(cfg.d, cfg.seed ^ 0x2FF_5EED);
            if cfg.rff_dim > 0 {
                rff = rff.with_dim(cfg.rff_dim);
            }
            run_churn_test_with(PositiveRffMap::new(rff), cfg)
        }
    }
}

/// The kernel-generic closed loop behind [`run_churn_test`].
pub fn run_churn_test_with<M: FeatureMap + Clone + 'static>(
    map: M,
    cfg: &ChurnConfig,
) -> ChurnReport {
    let sampler_name = format!("{}-streaming", map.name());
    let mut rng = Rng::new(cfg.seed);
    let mut emb = vec![0.0f32; cfg.n_classes * cfg.d];
    rng.fill_normal(&mut emb, 0.3);
    let mut tree = KernelTreeSampler::new(map, cfg.n_classes, None);
    tree.reset_embeddings(&emb, cfg.n_classes, cfg.d);
    let mut pubr = VocabPublisher::new(tree, None).with_policy(cfg.policy);
    // one registry over the stack: vocab tiers + the arena publish path
    let registry = MetricsRegistry::new();
    pubr.obs().register_into(&registry);
    pubr.tree_publisher().obs().register_into(&registry);
    let store = pubr.store();
    let obs = pubr.obs().clone();

    let stop = std::sync::atomic::AtomicBool::new(false);
    let mut latencies = Samples::new();
    let mut completed = 0u64;
    let mut misses = 0u64;
    let mut inserts = 0u64;
    let mut retires = 0u64;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for client in 0..cfg.clients as u64 {
            let store = store.clone();
            let obs = obs.clone();
            let name = sampler_name.clone();
            let (d, m, draws, deadline, seed) =
                (cfg.d, cfg.m, cfg.draws, cfg.deadline, cfg.seed);
            handles.push(scope.spawn(move || {
                let sampler = VocabSnapshotSampler::new(store, name, obs);
                let mut crng = Rng::new(seed ^ (0xC11E + client));
                let mut lats = Vec::with_capacity(draws);
                let mut missed = 0u64;
                let mut out = Sample::default();
                for _ in 0..draws {
                    let h: Vec<f32> = (0..d).map(|_| crng.normal_f32(0.0, 1.0)).collect();
                    let input = SampleInput { h: Some(&h), ..Default::default() };
                    sampler.refresh_snapshots();
                    let t = Instant::now();
                    sampler.sample(&input, m, &mut crng, &mut out).expect("churn draw failed");
                    let lat = t.elapsed();
                    // the scenario's correctness gate, per draw: strictly
                    // positive finite q, and the drawn class must be live in
                    // the generation it was drawn from — prob() runs against
                    // the same pinned snapshot and declines tombstoned or
                    // unknown ids, so Some(..) is exactly the liveness check
                    for (&c, &q) in out.classes.iter().zip(&out.q) {
                        assert!(q > 0.0 && q.is_finite(), "class {c} drew q {q}");
                        assert!(
                            sampler.prob(&input, c).is_some(),
                            "drew class {c} not live in its own generation"
                        );
                    }
                    lats.push(lat.as_secs_f64());
                    if lat > deadline {
                        missed += 1;
                    }
                }
                (lats, missed)
            }));
        }
        // the writer churns the catalog until every reader finishes
        let writer = {
            let stop = &stop;
            let pubr = &mut pubr;
            let (n0, d) = (cfg.n_classes, cfg.d);
            let (insert_every, retire_every, update_batch) =
                (cfg.insert_every, cfg.retire_every, cfg.update_batch);
            let seed = cfg.seed;
            scope.spawn(move || {
                let mut wrng = Rng::new(seed ^ 0xC4C4);
                // the writer's own view of the live id set (retire picks)
                let mut live: Vec<u32> = (0..n0 as u32).collect();
                let mut row = vec![0.0f32; d];
                let mut round = 0usize;
                let (mut ins, mut ret) = (0u64, 0u64);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    round += 1;
                    if insert_every > 0 && round % insert_every == 0 {
                        wrng.fill_normal(&mut row, 0.3);
                        let (id, _) = pubr.insert_class(&row);
                        live.push(id);
                        ins += 1;
                    }
                    if retire_every > 0 && round % retire_every == 0 && live.len() > 2 {
                        let pick = wrng.below(live.len() as u64) as usize;
                        if pubr.retire_class(live[pick]) {
                            live.swap_remove(pick);
                            ret += 1;
                        }
                    }
                    if update_batch > 0 && !live.is_empty() {
                        let k = update_batch.min(live.len());
                        let mut ids: Vec<usize> = (0..k)
                            .map(|_| live[wrng.below(live.len() as u64) as usize] as usize)
                            .collect();
                        ids.sort_unstable();
                        ids.dedup();
                        let mut flat = vec![0.0f32; ids.len() * d];
                        wrng.fill_normal(&mut flat, 0.3);
                        pubr.update_many(&ids, &flat);
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                (ins, ret)
            })
        };
        for handle in handles {
            let (lats, missed) = handle.join().expect("churn reader panicked");
            completed += lats.len() as u64;
            for l in lats {
                latencies.push(l);
            }
            misses += missed;
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let (ins, ret) = writer.join().expect("churn writer panicked");
        inserts = ins;
        retires = ret;
    });
    // end-of-run drain fold: flush the memtable and tombstones so the
    // reported catalog (and the exported compaction series) reflect a
    // clean arena — and so short runs still exercise the barrier path
    pubr.compact();
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics_text = registry.snapshot().render_prometheus();
    if let Some(path) = &cfg.metrics_path {
        if let Err(e) = std::fs::write(path, &metrics_text) {
            eprintln!("warning: could not write metrics exposition to {}: {e}", path.display());
        }
    }
    let lat = latencies.percentiles(&[50.0, 95.0, 100.0]);
    ChurnReport {
        draws: completed,
        wall_s,
        throughput_rps: completed as f64 / wall_s.max(1e-9),
        latency_p50_s: lat[0],
        latency_p95_s: lat[1],
        latency_max_s: lat[2],
        deadline_miss_rate: if completed == 0 { 1.0 } else { misses as f64 / completed as f64 },
        inserts,
        retires,
        compactions: obs.compactions(),
        live_classes: pubr.live_len(),
        tier_arena: obs.tier_arena_total(),
        tier_memtable: obs.tier_memtable_total(),
        metrics_text,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_test_smoke() {
        // tiny end-to-end pass of the whole serving stack: every request
        // answered, writer published, nothing panicked
        let cfg = LoadGenConfig {
            n_classes: 400,
            d: 4,
            shards: 3,
            workers: 2,
            clients: 3,
            requests: 60,
            m: 4,
            updates_per_publish: 8,
            deadline: Duration::from_secs(5),
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 512,
            },
            ..Default::default()
        };
        let report = run_load_test(&cfg);
        // 1/16 of requests are topk calls
        assert!(report.completed > 0 && report.topk_calls > 0, "{report:?}");
        assert_eq!(
            report.completed + report.topk_calls,
            (cfg.clients * cfg.requests) as u64 - report.rejected,
        );
        assert!(report.publishes > 0, "writer never published: {report:?}");
        assert!(report.deadline_miss_rate < 1.0);
        assert!(report.latency_p50_s >= 0.0 && report.latency_p95_s >= report.latency_p50_s);
        // the exit exposition carries every serve-stack series: requests
        // flowed, shards published, and both are visible by canonical name
        let text = &report.metrics_text;
        for series in [
            "kss_batcher_submitted_total",
            "kss_batcher_queue_depth_max",
            "kss_batcher_shed_total",
            "kss_batcher_coalesce_rows_count",
            "kss_service_dropped_reply_total",
            "kss_publish_lag_seconds_count",
            "kss_publish_swap_seconds_count",
        ] {
            assert!(text.contains(series), "missing series {series} in:\n{text}");
        }
        // nonzero where the smoke guarantees traffic
        assert!(!text.contains("kss_batcher_submitted_total 0\n"), "no submits recorded");
        assert!(!text.contains("kss_publish_lag_seconds_count 0\n"), "no publish lag recorded");
    }

    #[test]
    fn load_test_smoke_midx() {
        // the closed loop with worker draws routed through the inverted
        // multi-index (single shard): requests flow, the writer's
        // publishes force warm index rebuilds, and the kss_sampler_midx_*
        // series land in the exit exposition
        let cfg = LoadGenConfig {
            n_classes: 400,
            d: 4,
            shards: 1,
            workers: 2,
            clients: 3,
            requests: 60,
            m: 4,
            updates_per_publish: 8,
            deadline: Duration::from_secs(5),
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 512,
            },
            midx_clusters: 20,
            ..Default::default()
        };
        let report = run_load_test(&cfg);
        assert!(report.completed > 0 && report.topk_calls > 0, "{report:?}");
        assert!(report.publishes > 0, "writer never published: {report:?}");
        let text = &report.metrics_text;
        for series in [
            "kss_sampler_midx_clusters",
            "kss_sampler_midx_coarse_draw_total",
            "kss_sampler_midx_refine_total",
            "kss_sampler_midx_reassign_total",
        ] {
            assert!(text.contains(series), "missing series {series} in:\n{text}");
        }
        assert!(text.contains("kss_sampler_midx_clusters 20\n"), "cluster gauge wrong:\n{text}");
        assert!(
            !text.contains("kss_sampler_midx_coarse_draw_total 0\n"),
            "no coarse draws recorded"
        );
    }

    #[test]
    fn load_test_smoke_rff_kernel() {
        // the same closed loop over the random-feature kernel: publishing,
        // sampling, retrieval and the writer all run kernel-generic
        let cfg = LoadGenConfig {
            n_classes: 300,
            d: 4,
            kernel: ServeKernel::Rff,
            rff_dim: 0, // registry default D = 4d
            shards: 3,
            workers: 2,
            clients: 2,
            requests: 40,
            m: 4,
            updates_per_publish: 8,
            deadline: Duration::from_secs(5),
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 512,
            },
            ..Default::default()
        };
        let report = run_load_test(&cfg);
        assert!(report.completed > 0 && report.topk_calls > 0, "{report:?}");
        assert!(report.publishes > 0, "writer never published: {report:?}");
    }

    #[test]
    fn churn_smoke() {
        // the streaming vocabulary under live traffic: readers assert
        // q-positivity and liveness per draw (violations panic), the
        // writer churns classes, and the exit exposition carries every
        // vocab series by canonical name
        let cfg = ChurnConfig {
            n_classes: 300,
            d: 4,
            clients: 3,
            draws: 120,
            m: 6,
            insert_every: 1,
            retire_every: 2,
            update_batch: 8,
            policy: CompactionPolicy { memtable_cap: 16, max_tombstone_frac: 0.25 },
            deadline: Duration::from_secs(5),
            ..Default::default()
        };
        let report = run_churn_test(&cfg);
        assert_eq!(report.draws, (cfg.clients * cfg.draws) as u64);
        assert!(report.inserts > 0, "writer never inserted: {report:?}");
        assert!(report.retires > 0, "writer never retired: {report:?}");
        assert!(report.compactions > 0, "no fold ran (drain guarantees one): {report:?}");
        assert!(report.tier_arena > 0, "no draw routed to the arena tier");
        assert!(report.deadline_miss_rate < 1.0);
        assert_eq!(
            report.tier_arena + report.tier_memtable,
            report.draws * cfg.m as u64,
            "tier routing must account for every negative"
        );
        // the drained catalog balances: initial + inserts − retires
        assert_eq!(
            report.live_classes as u64,
            cfg.n_classes as u64 + report.inserts - report.retires,
        );
        let text = &report.metrics_text;
        for series in [
            "kss_vocab_memtable_size",
            "kss_vocab_tombstones",
            "kss_vocab_compaction_seconds_count",
            "kss_vocab_compaction_lag_ops_count",
            "kss_vocab_tier_arena_total",
            "kss_vocab_tier_memtable_total",
            "kss_vocab_insert_total",
            "kss_vocab_retire_total",
            "kss_publish_compact_total",
        ] {
            assert!(text.contains(series), "missing series {series} in:\n{text}");
        }
        assert!(!text.contains("kss_vocab_insert_total 0\n"), "no inserts recorded");
        assert!(!text.contains("kss_vocab_tier_arena_total 0\n"), "no arena draws recorded");
        assert!(
            !text.contains("kss_vocab_compaction_seconds_count 0\n"),
            "no compactions recorded"
        );
    }

    #[test]
    fn churn_smoke_rff_kernel() {
        // the same loop over the random-feature kernel — tier masses and
        // tombstone exclusion are kernel-generic
        let cfg = ChurnConfig {
            n_classes: 200,
            d: 4,
            kernel: ServeKernel::Rff,
            clients: 2,
            draws: 60,
            m: 4,
            policy: CompactionPolicy { memtable_cap: 12, max_tombstone_frac: 0.25 },
            deadline: Duration::from_secs(5),
            ..Default::default()
        };
        let report = run_churn_test(&cfg);
        assert_eq!(report.draws, (cfg.clients * cfg.draws) as u64);
        assert!(report.inserts > 0 && report.compactions > 0, "{report:?}");
    }

    #[test]
    fn serve_kernel_parses() {
        assert_eq!(ServeKernel::parse("quadratic").unwrap(), ServeKernel::Quadratic);
        assert_eq!(ServeKernel::parse("rff").unwrap(), ServeKernel::Rff);
        assert!(ServeKernel::parse("cubic").is_err());
    }
}
