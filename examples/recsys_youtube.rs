//! The paper's recommender-system workload: next-watch retrieval over a
//! 10k-video catalog (YouTube10k shape), comparing sampling distributions
//! at a fixed sample size.
//!
//! ```sh
//! cargo run --release --example recsys_youtube
//! KSS_RS_EPOCHS=3 KSS_RS_EVENTS=20000 cargo run --release --example recsys_youtube
//! ```

use kss::coordinator::{run_grid, GridSpec, TrainConfig};
use kss::runtime::Engine;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let epochs: usize = std::env::var("KSS_RS_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(2);
    let events: usize = std::env::var("KSS_RS_EVENTS").ok().and_then(|s| s.parse().ok()).unwrap_or(12_000);
    let engine = Engine::new(Path::new("artifacts"))?;

    println!("YouTube-style retrieval: 10k videos, {events} events, {epochs} epochs, m = 32\n");
    let grid = GridSpec {
        base: TrainConfig {
            model: "yt10k".into(),
            m: 32,
            lr: 0.25,
            epochs,
            train_size: events,
            valid_size: events / 8,
            eval_batches: 10,
            seed: 7,
            ..Default::default()
        },
        samplers: vec!["uniform".into(), "unigram".into(), "quadratic".into(), "softmax".into()],
        ms: vec![32],
        include_full: true,
    };
    let summaries = run_grid(&engine, &grid, Some(Path::new("runs")))?;

    println!("\nfinal full-softmax eval loss (lower = better):");
    println!("{:<16} {:>10} {:>10}", "sampler", "loss", "wall(s)");
    for s in &summaries {
        println!("{:<16} {:>10.4} {:>10.1}", s.label(), s.final_loss, s.wall_s);
    }
    println!("\nExpected shape (paper Fig. 2 middle): softmax ≈ full softmax;");
    println!("quadratic close behind; unigram helps over uniform (popularity");
    println!("skew) but cannot follow the model like the kernel sampler does.");
    Ok(())
}
