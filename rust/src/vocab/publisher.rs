//! Serve-side streaming vocabulary: the writer/reader split of
//! [`crate::vocab::streaming::StreamingKernelSampler`].
//!
//! [`VocabPublisher`] owns the mutable state (memtable, tombstones, the
//! arena's [`crate::serve::snapshot::TreePublisher`]) and, after **every**
//! mutation, publishes one immutable [`VocabSnapshot`] binding the tiers
//! together — a reader can never observe a memtable from one generation
//! next to an arena from another. [`VocabSnapshotSampler`] is the wait-free
//! read face: it pins a composite generation, draws through the same
//! [`crate::vocab::streaming::draw_from_tiers`] body the trainer sampler
//! runs (bit-identical streams, property-tested below), and advances only
//! in [`Sampler::refresh_snapshots`] — the serve layer's determinism
//! contract, inherited wholesale from
//! [`crate::serve::reader_sampler::SnapshotSampler`].
//!
//! Compaction goes through
//! [`crate::serve::snapshot::TreePublisher::compact_and_publish`]: the
//! replay log takes a `Compact` barrier record, pre-barrier arenas leave
//! the reclaim queue, and the next composite snapshot carries the rebuilt
//! arena with an empty memtable and no tombstones.

use crate::sampler::kernel::tree::KernelTreeSampler;
use crate::sampler::kernel::FeatureMap;
use crate::sampler::{Needs, Sample, SampleInput, Sampler};
use crate::serve::snapshot::{
    PublishReport, SnapshotReader, SnapshotStore, TreePublisher, TreeSnapshot,
};
use crate::util::rng::Rng;
use crate::util::threadpool::Pool;
use crate::vocab::memtable::{Memtable, TombstoneSet};
use crate::vocab::streaming::{draw_from_tiers, prob_from_tiers, TierScratch};
use crate::vocab::{CompactionPolicy, VocabObs};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};

/// One immutable composite generation: the arena tree snapshot plus the
/// memtable/tombstone state it was published with. Readers draw from all
/// tiers of one `VocabSnapshot` — never mixing generations.
pub struct VocabSnapshot<M: FeatureMap> {
    /// Composite generation (0 = initial publish); advances on every
    /// mutation, not in lockstep with the arena tree's own generation.
    pub generation: u64,
    /// The arena tier: a frozen tree generation from the inner
    /// [`TreePublisher`].
    pub tree: Arc<TreeSnapshot<M>>,
    /// Arena slot → global class id.
    pub arena_ids: Arc<Vec<u32>>,
    /// Global class id → arena slot (tombstoned slots stay mapped).
    pub arena_index: Arc<HashMap<u32, u32>>,
    /// The memtable tier, frozen at publish time.
    pub memtable: Arc<Memtable>,
    /// Tombstoned arena slots with their frozen rows.
    pub tombstones: Arc<TombstoneSet>,
}

/// Writer side of the serve-path streaming vocabulary (see module docs).
pub struct VocabPublisher<M: FeatureMap + Clone> {
    inner: TreePublisher<M>,
    tree_store: Arc<SnapshotStore<TreeSnapshot<M>>>,
    store: Arc<SnapshotStore<VocabSnapshot<M>>>,
    arena_ids: Arc<Vec<u32>>,
    arena_index: Arc<HashMap<u32, u32>>,
    memtable: Memtable,
    tombs: TombstoneSet,
    next_id: u32,
    policy: CompactionPolicy,
    leaf_size: Option<usize>,
    composite_gen: u64,
    ops_since_compact: u64,
    obs: VocabObs,
}

impl<M: FeatureMap + Clone> VocabPublisher<M> {
    /// Wrap a seeded arena tree (dense global ids `0..n`) and publish the
    /// composite generation 0.
    pub fn new(tree: KernelTreeSampler<M>, leaf_size: Option<usize>) -> VocabPublisher<M> {
        let n = tree.num_classes();
        let d = tree.embed_dim();
        let inner = TreePublisher::new(tree);
        let tree_store = inner.store();
        let (_, tree_snap) = tree_store.load();
        let arena_ids: Arc<Vec<u32>> = Arc::new((0..n as u32).collect());
        let arena_index: Arc<HashMap<u32, u32>> =
            Arc::new((0..n as u32).map(|i| (i, i)).collect());
        let store = Arc::new(SnapshotStore::new(VocabSnapshot {
            generation: 0,
            tree: tree_snap,
            arena_ids: arena_ids.clone(),
            arena_index: arena_index.clone(),
            memtable: Arc::new(Memtable::new(d)),
            tombstones: Arc::new(TombstoneSet::new(d)),
        }));
        VocabPublisher {
            inner,
            tree_store,
            store,
            arena_ids,
            arena_index,
            memtable: Memtable::new(d),
            tombs: TombstoneSet::new(d),
            next_id: n as u32,
            policy: CompactionPolicy::default(),
            leaf_size,
            composite_gen: 0,
            ops_since_compact: 0,
            obs: VocabObs::default(),
        }
    }

    pub fn with_policy(mut self, policy: CompactionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// The composite publish point readers subscribe to.
    pub fn store(&self) -> Arc<SnapshotStore<VocabSnapshot<M>>> {
        self.store.clone()
    }

    /// Telemetry cells (shared with every [`VocabSnapshotSampler`] built
    /// via [`VocabPublisher::reader`]).
    pub fn obs(&self) -> &VocabObs {
        &self.obs
    }

    /// The inner arena publisher's telemetry/stat surface.
    pub fn tree_publisher(&self) -> &TreePublisher<M> {
        &self.inner
    }

    /// A read-only sampler pinned to the current composite generation.
    pub fn reader(&self, name: impl Into<String>) -> VocabSnapshotSampler<M> {
        VocabSnapshotSampler::new(self.store(), name.into(), self.obs.clone())
    }

    fn d(&self) -> usize {
        self.memtable.d()
    }

    pub fn live_len(&self) -> usize {
        self.arena_ids.len() - self.tombs.len() + self.memtable.len()
    }

    pub fn memtable_len(&self) -> usize {
        self.memtable.len()
    }

    pub fn tombstone_len(&self) -> usize {
        self.tombs.len()
    }

    pub fn is_live(&self, id: u32) -> bool {
        self.memtable.contains(id)
            || self.arena_index.get(&id).is_some_and(|&slot| !self.tombs.contains(slot))
    }

    /// Bind the composite tiers at the current generation and swap them in.
    /// Called after every mutation — the one place composite snapshots are
    /// minted, so tier mixing is structurally impossible.
    fn republish(&mut self) -> u64 {
        let (_, tree_snap) = self.tree_store.load();
        self.composite_gen += 1;
        let snap = VocabSnapshot {
            generation: self.composite_gen,
            tree: tree_snap,
            arena_ids: self.arena_ids.clone(),
            arena_index: self.arena_index.clone(),
            memtable: Arc::new(self.memtable.clone()),
            tombstones: Arc::new(self.tombs.clone()),
        };
        let g = self.store.publish(Arc::new(snap));
        debug_assert_eq!(g, self.composite_gen);
        self.obs.memtable_size.set(self.memtable.len() as f64);
        self.obs.tombstones.set(self.tombs.len() as f64);
        g
    }

    /// Insert a new class with a fresh id; returns (id, composite gen).
    pub fn insert_class(&mut self, row: &[f32]) -> (u32, u64) {
        let id = self.next_id;
        let g = self.insert_class_with_id(id, row).expect("fresh id cannot be live");
        (id, g)
    }

    /// Insert under a caller-chosen id (errors if live; a tombstoned id may
    /// be re-inserted — the arena copy stays masked until compaction).
    pub fn insert_class_with_id(&mut self, id: u32, row: &[f32]) -> Result<u64> {
        anyhow::ensure!(!self.is_live(id), "class {id} is already live");
        self.memtable.insert(id, row)?;
        self.next_id = self.next_id.max(id.saturating_add(1));
        self.obs.inserts.inc();
        self.ops_since_compact += 1;
        let g = self.republish();
        self.maybe_compact();
        Ok(g)
    }

    /// Retire a live class (memtable residents leave the memtable, arena
    /// classes are tombstoned). Returns false for non-live ids and refuses
    /// to retire the last live class.
    pub fn retire_class(&mut self, id: u32) -> bool {
        if self.live_len() <= 1 {
            return false;
        }
        if self.memtable.remove(id) {
            self.obs.retires.inc();
            self.ops_since_compact += 1;
            self.republish();
            return true;
        }
        let Some(&slot) = self.arena_index.get(&id) else {
            return false;
        };
        if self.tombs.contains(slot) {
            return false;
        }
        let row = self.inner.shadow().emb_row(slot as usize).to_vec();
        self.tombs.insert(slot, &row);
        self.obs.retires.inc();
        self.ops_since_compact += 1;
        self.republish();
        self.maybe_compact();
        true
    }

    /// Churn-aware batched update over *global* ids: memtable rows patch in
    /// place, tombstoned/unknown ids are dropped (counted), the rest goes
    /// through the arena publisher as one slot-sorted
    /// `update_and_publish`. Returns the publish report when the arena was
    /// touched.
    pub fn update_many(&mut self, classes: &[usize], rows: &[f32]) -> Option<PublishReport> {
        if classes.is_empty() {
            return None;
        }
        let d = rows.len() / classes.len();
        debug_assert_eq!(d, self.d());
        let mut arena: Vec<(u32, usize)> = Vec::new();
        for (i, &gid) in classes.iter().enumerate() {
            let gid = gid as u32;
            let row = &rows[i * d..(i + 1) * d];
            if self.memtable.update_row(gid, row) {
                continue;
            }
            match self.arena_index.get(&gid) {
                Some(&slot) if !self.tombs.contains(slot) => arena.push((slot, i)),
                _ => self.obs.dropped_updates.inc(),
            }
        }
        self.ops_since_compact += 1;
        let report = if arena.is_empty() {
            None
        } else {
            arena.sort_unstable_by_key(|&(slot, _)| slot);
            let mut slots = Vec::with_capacity(arena.len());
            let mut flat = Vec::with_capacity(arena.len() * d);
            for &(slot, i) in &arena {
                slots.push(slot as usize);
                flat.extend_from_slice(&rows[i * d..(i + 1) * d]);
            }
            Some(self.inner.update_and_publish(&slots, &flat))
        };
        self.republish();
        report
    }

    /// The live class set in canonical compaction order (arena slots
    /// ascending, tombstones skipped, then memtable slots) — the layout
    /// [`VocabPublisher::compact`] rebuilds from.
    pub fn live_classes(&self) -> (Vec<u32>, Vec<f32>) {
        let d = self.d();
        let shadow = self.inner.shadow();
        let n = self.arena_ids.len();
        let live = self.live_len();
        let mut ids = Vec::with_capacity(live);
        let mut rows = Vec::with_capacity(live * d);
        for slot in 0..n {
            if self.tombs.contains(slot as u32) {
                continue;
            }
            ids.push(self.arena_ids[slot]);
            rows.extend_from_slice(shadow.emb_row(slot));
        }
        ids.extend_from_slice(self.memtable.ids());
        rows.extend_from_slice(self.memtable.rows());
        (ids, rows)
    }

    /// Fold the memtable into the arena and drop tombstones through the
    /// replay-log barrier (`compact_and_publish`), then publish the clean
    /// composite generation. The rebuilt arena is bitwise-equal to a
    /// from-scratch tree over the live set by construction.
    pub fn compact(&mut self) -> PublishReport {
        let t = std::time::Instant::now();
        let (ids, rows) = self.live_classes();
        let d = self.d();
        let n = ids.len();
        let map = self.inner.shadow().feature_map().clone();
        let mut tree = KernelTreeSampler::new(map, n, self.leaf_size);
        tree.reset_embeddings(&rows, n, d);
        let report = self.inner.compact_and_publish(tree);
        self.arena_index =
            Arc::new(ids.iter().enumerate().map(|(slot, &gid)| (gid, slot as u32)).collect());
        self.arena_ids = Arc::new(ids);
        self.memtable.clear();
        self.tombs.clear();
        self.obs.compaction_seconds.record(t.elapsed().as_secs_f64());
        self.obs.compaction_lag_ops.record(self.ops_since_compact as f64);
        self.ops_since_compact = 0;
        self.republish();
        report
    }

    fn maybe_compact(&mut self) {
        if self.policy.should_compact(
            self.arena_ids.len(),
            self.tombs.len(),
            self.memtable.len(),
        ) {
            self.compact();
        }
    }
}

/// The pinned composite generation, refreshed only in
/// [`Sampler::refresh_snapshots`].
struct PinnedVocab<M: FeatureMap> {
    reader: SnapshotReader<VocabSnapshot<M>>,
    snap: Arc<VocabSnapshot<M>>,
}

/// Read-only [`Sampler`] over composite streaming-vocabulary generations
/// (the `SnapshotSampler` protocol — pinned `Arc` cloned out of a short
/// lock, wait-free draws, poison recovered not propagated).
pub struct VocabSnapshotSampler<M: FeatureMap + Clone> {
    name: String,
    d: usize,
    pinned: Mutex<PinnedVocab<M>>,
    scratch_pool: Pool<TierScratch>,
    obs: VocabObs,
}

impl<M: FeatureMap + Clone> VocabSnapshotSampler<M> {
    pub fn new(
        store: Arc<SnapshotStore<VocabSnapshot<M>>>,
        name: String,
        obs: VocabObs,
    ) -> VocabSnapshotSampler<M> {
        let reader = SnapshotReader::new(store);
        let snap = reader.pinned().clone();
        let d = snap.tree.tree.embed_dim();
        VocabSnapshotSampler {
            name,
            d,
            pinned: Mutex::new(PinnedVocab { reader, snap }),
            scratch_pool: Pool::new(),
            obs,
        }
    }

    fn pin(&self) -> Result<Arc<VocabSnapshot<M>>> {
        let guard = self
            .pinned
            .lock()
            .map_err(|_| anyhow::anyhow!("vocab snapshot sampler lock poisoned"))?;
        Ok(guard.snap.clone())
    }
}

impl<M: FeatureMap + Clone> Sampler for VocabSnapshotSampler<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        out.clear();
        let h = input
            .h
            .ok_or_else(|| anyhow::anyhow!("sampler '{}' needs the query embedding h", self.name))?;
        anyhow::ensure!(h.len() == self.d, "h len {} != d {}", h.len(), self.d);
        let snap = self.pin()?;
        let mut s = self.scratch_pool.take(TierScratch::default);
        let res = draw_from_tiers(
            &snap.tree.tree,
            &snap.arena_ids,
            &snap.memtable,
            &snap.tombstones,
            h,
            m,
            &mut s,
            rng,
            &self.obs,
            out,
        );
        self.scratch_pool.put(s);
        res
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let h = input.h?;
        let snap = self.pin().ok()?;
        prob_from_tiers(
            &snap.tree.tree,
            &snap.arena_index,
            &snap.memtable,
            &snap.tombstones,
            h,
            class,
        )
    }

    /// Read-only: the vocabulary lives in the publisher.
    fn update(&mut self, _class: usize, _w_new: &[f32]) {
        debug_assert!(
            false,
            "snapshot-backed sampler is read-only; route updates through the publisher"
        );
    }

    fn update_many(&mut self, _classes: &[usize], _rows: &[f32]) {
        debug_assert!(
            false,
            "snapshot-backed sampler is read-only; route updates through the publisher"
        );
    }

    fn reset_embeddings(&mut self, _w: &[f32], _n: usize, _d: usize) {
        debug_assert!(
            false,
            "snapshot-backed sampler is read-only; seed the publisher's tree instead"
        );
    }

    fn snapshot_backed(&self) -> bool {
        true
    }

    /// Advance to the freshest composite generation — the only place the
    /// pinned snapshot changes. Poison is recovered: refresh overwrites the
    /// whole pinned state.
    fn refresh_snapshots(&self) {
        let mut guard = self.pinned.lock().unwrap_or_else(PoisonError::into_inner);
        let PinnedVocab { reader, snap } = &mut *guard;
        *snap = reader.current().clone();
    }

    fn pinned_generation(&self) -> Option<u64> {
        let guard = self.pinned.lock().unwrap_or_else(PoisonError::into_inner);
        Some(guard.snap.generation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::vocab::StreamingKernelSampler;

    const ALPHA: f64 = 100.0;

    fn seeded_tree(n: usize, d: usize, seed: u64) -> (KernelTreeSampler<QuadraticMap>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let mut t = KernelTreeSampler::new(QuadraticMap::new(d, ALPHA), n, Some(4));
        t.reset_embeddings(&emb, n, d);
        (t, emb)
    }

    fn draw(s: &dyn Sampler, h: &[f32], m: usize, seed: u64) -> (Vec<u32>, Vec<f64>) {
        let input = SampleInput { h: Some(h), ..Default::default() };
        let mut out = Sample::default();
        s.sample(&input, m, &mut Rng::new(seed), &mut out).unwrap();
        (out.classes, out.q)
    }

    #[test]
    fn publisher_reader_matches_owning_streaming_sampler_bitwise() {
        // same op sequence through both faces of the subsystem → identical
        // (class, q) streams bit for bit: the reader runs the exact same
        // draw_from_tiers body over the exact same tier state
        let (n, d) = (24usize, 3usize);
        let (tree, emb) = seeded_tree(n, d, 91);
        let mut pubr =
            VocabPublisher::new(tree, Some(4)).with_policy(CompactionPolicy::manual());
        let mut own = StreamingKernelSampler::new(QuadraticMap::new(d, ALPHA), n, Some(4))
            .with_policy(CompactionPolicy::manual());
        own.reset_embeddings(&emb, n, d);
        let reader = pubr.reader("quadratic-streaming");
        assert_eq!(reader.name(), "quadratic-streaming");
        assert!(reader.snapshot_backed());

        let mut rng = Rng::new(17);
        let h = vec![0.4f32, -0.7, 0.2];
        for step in 0..24u64 {
            match step % 6 {
                0 | 3 => {
                    let mut row = vec![0.0f32; d];
                    rng.fill_normal(&mut row, 0.5);
                    let (id, _) = pubr.insert_class(&row);
                    assert_eq!(own.insert_class(&row), id);
                }
                1 => {
                    // retire a live arena class deterministically
                    let gid = (step as u32 * 5) % n as u32;
                    assert_eq!(pubr.retire_class(gid), own.retire_class(gid));
                }
                4 => {
                    pubr.compact();
                    own.compact();
                }
                _ => {
                    let gid = (step as usize * 7) % n;
                    let mut row = vec![0.0f32; d];
                    rng.fill_normal(&mut row, 0.5);
                    pubr.update_many(&[gid], &row);
                    own.update_many(&[gid], &row);
                }
            }
            assert_eq!(pubr.live_len(), own.live_len(), "step {step}");
            reader.refresh_snapshots();
            let a = draw(&reader, &h, 12, 0xBEEF ^ step);
            let b = draw(&own, &h, 12, 0xBEEF ^ step);
            assert_eq!(a.0, b.0, "step {step}: classes diverged");
            assert_eq!(a.1, b.1, "step {step}: q diverged");
            for &gid in a.0.iter().take(4) {
                let input = SampleInput { h: Some(&h), ..Default::default() };
                assert_eq!(reader.prob(&input, gid), own.prob(&input, gid), "step {step}");
            }
        }
    }

    #[test]
    fn composite_generation_is_pinned_until_refresh() {
        let (n, d) = (16usize, 2usize);
        let (tree, _) = seeded_tree(n, d, 92);
        let mut pubr =
            VocabPublisher::new(tree, Some(4)).with_policy(CompactionPolicy::manual());
        let reader = pubr.reader("quadratic-streaming");
        assert_eq!(reader.pinned_generation(), Some(0));
        let h = vec![0.6f32, -0.3];
        let before = draw(&reader, &h, 16, 7);
        // tier-coherent mutations land; the pinned composite must not move
        let mut rng = Rng::new(5);
        let mut row = vec![0.0f32; d];
        rng.fill_normal(&mut row, 0.5);
        pubr.insert_class(&row);
        pubr.retire_class(3);
        assert_eq!(reader.pinned_generation(), Some(0), "pinned set moved without refresh");
        assert_eq!(draw(&reader, &h, 16, 7), before, "draws changed under a pinned generation");
        reader.refresh_snapshots();
        assert_eq!(reader.pinned_generation(), Some(2));
        // the refreshed snapshot sees both tiers at once: the insert is
        // drawable, the tombstone is not
        let inserted = n as u32;
        let (classes, _) = draw(&reader, &h, 400, 8);
        assert!(classes.contains(&inserted), "inserted class never drawn");
        assert!(!classes.contains(&3), "tombstoned class drawn");
    }

    #[test]
    fn compaction_publishes_through_the_replay_log_barrier() {
        let (n, d) = (20usize, 2usize);
        let (tree, _) = seeded_tree(n, d, 93);
        let mut pubr =
            VocabPublisher::new(tree, Some(4)).with_policy(CompactionPolicy::manual());
        // hold a pre-compaction composite pinned (its arena must survive)
        let pinned = pubr.reader("quadratic-streaming");
        let h = vec![0.2f32, 0.9];
        let before = draw(&pinned, &h, 10, 3);
        let mut rng = Rng::new(9);
        let mut row = vec![0.0f32; d];
        for _ in 0..3 {
            rng.fill_normal(&mut row, 0.5);
            pubr.insert_class(&row);
        }
        pubr.retire_class(7);
        let report = pubr.compact();
        assert!(!report.reclaimed, "fresh topology cannot reclaim an arena");
        assert_eq!(pubr.tree_publisher().stats.compactions, 1);
        assert_eq!(pubr.memtable_len(), 0);
        assert_eq!(pubr.tombstone_len(), 0);
        assert_eq!(pubr.live_len(), n - 1 + 3);
        assert_eq!(pubr.obs().compactions(), 1);
        // the pinned reader still draws generation-0 bits
        assert_eq!(draw(&pinned, &h, 10, 3), before, "pinned pre-barrier draws changed");
        // a fresh reader sees the folded catalog: memtable ids moved into
        // the arena, the tombstoned id is gone
        pinned.refresh_snapshots();
        let (classes, q) = draw(&pinned, &h, 600, 4);
        assert!(classes.iter().all(|&c| c != 7), "retired class survived compaction");
        assert!(classes.iter().any(|&c| c >= n as u32), "folded memtable class never drawn");
        assert!(q.iter().all(|&x| x > 0.0 && x.is_finite()));
        // post-compaction updates flow through the arena publisher again
        rng.fill_normal(&mut row, 0.5);
        let rep = pubr.update_many(&[2], &row).expect("arena update must publish");
        assert!(rep.generation > report.generation);
    }

    #[test]
    fn concurrent_readers_survive_churn_and_compactions() {
        let (n, d) = (32usize, 3usize);
        let (tree, _) = seeded_tree(n, d, 94);
        let mut pubr = VocabPublisher::new(tree, Some(4))
            .with_policy(CompactionPolicy { memtable_cap: 8, max_tombstone_frac: 0.25 });
        let store = pubr.store();
        let obs = pubr.obs().clone();
        let stop = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|scope| {
            for t in 0..3u64 {
                let store = store.clone();
                let obs = obs.clone();
                let stop = &stop;
                scope.spawn(move || {
                    let s = VocabSnapshotSampler::new(store, "quadratic-streaming".into(), obs);
                    let h = vec![0.5f32, -0.2, 0.8];
                    let input = SampleInput { h: Some(&h), ..Default::default() };
                    let mut out = Sample::default();
                    let mut rng = Rng::new(0xD00D + t);
                    let mut draws = 0u64;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) || draws < 50 {
                        s.refresh_snapshots();
                        s.sample(&input, 8, &mut rng, &mut out).unwrap();
                        for (&c, &q) in out.classes.iter().zip(&out.q) {
                            assert!(q > 0.0 && q.is_finite(), "class {c} q {q}");
                        }
                        draws += 1;
                    }
                });
            }
            let mut rng = Rng::new(77);
            let mut row = vec![0.0f32; d];
            for i in 0..120u32 {
                rng.fill_normal(&mut row, 0.5);
                let (id, _) = pubr.insert_class(&row);
                if i % 3 == 0 {
                    pubr.retire_class(id / 2);
                }
                rng.fill_normal(&mut row, 0.5);
                pubr.update_many(&[(i as usize) % pubr.live_len().max(1)], &row);
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        assert!(pubr.obs().compactions() > 0, "policy never compacted under churn");
        assert!(pubr.tree_publisher().stats.compactions > 0);
    }
}
