//! Cross-module integration tests: manifest → engine → trainer → metrics,
//! plus failure injection (missing/corrupt artifacts, bad configs).
//!
//! These run against the real artifacts directory when present (skipped on a
//! fresh checkout so `cargo test` works before `make artifacts`).

use kss::coordinator::{run_grid, GridSpec, MetricsSink, TrainConfig, Trainer};
use kss::runtime::{Engine, Manifest, ParamStore, Tensor};
use kss::util::json;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! engine_or_skip {
    () => {
        match artifacts_dir() {
            Some(dir) => Engine::new(&dir).unwrap(),
            None => {
                eprintln!("artifacts not built; skipping");
                return;
            }
        }
    };
}

// ---------------------------------------------------------------------------
// full pipeline
// ---------------------------------------------------------------------------

#[test]
fn encode_step_eval_roundtrip_tiny() {
    let engine = engine_or_skip!();
    let spec = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&spec.params, 5).unwrap();

    // encode: h must be (batch, d) and finite
    let op = spec.op("encode").unwrap();
    let mut owned: Vec<Tensor> = store.values().to_vec();
    owned.push(Tensor::f32s(&[spec.batch, 8], vec![0.1; spec.batch * 8]));
    owned.push(Tensor::i32s(&[spec.batch, 3], vec![1; spec.batch * 3]));
    let args: Vec<&Tensor> = owned.iter().collect();
    let out = engine.execute(op, spec.params.len(), &args).unwrap();
    assert_eq!(out[0].shape(), &[spec.batch, spec.d]);
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));

    // score_all must equal h @ out_w^T at a spot-checked element
    let op = spec.op("score_all").unwrap();
    let args: Vec<&Tensor> = owned.iter().collect();
    let scores = engine.execute(op, spec.params.len(), &args).unwrap();
    assert_eq!(scores[0].shape(), &[spec.batch, spec.n_classes]);
    let h = out[0].as_f32().unwrap();
    let w0 = store.out_row(0);
    let want: f32 = h[..spec.d].iter().zip(w0).map(|(&a, &b)| a * b).sum();
    let got = scores[0].as_f32().unwrap()[0];
    assert!((got - want).abs() < 1e-4, "{got} vs {want}");
}

#[test]
fn grid_runner_writes_metrics_and_summary() {
    let engine = engine_or_skip!();
    let out_dir = std::env::temp_dir().join(format!("kss-grid-{}", std::process::id()));
    let grid = GridSpec {
        base: TrainConfig {
            model: "tiny".into(),
            epochs: 1,
            train_size: 320,
            valid_size: 160,
            eval_batches: 3,
            max_steps_per_epoch: 10,
            ..Default::default()
        },
        samplers: vec!["uniform".into()],
        ms: vec![4],
        include_full: false,
    };
    let summaries = run_grid(&engine, &grid, Some(&out_dir)).unwrap();
    assert_eq!(summaries.len(), 1);
    // per-run jsonl exists and parses; has config + eval records
    let files: Vec<_> = std::fs::read_dir(&out_dir).unwrap().collect();
    assert!(files.len() >= 2, "expected run jsonl + summary.json");
    let summary = std::fs::read_to_string(out_dir.join("summary.json")).unwrap();
    let v = json::parse(&summary).unwrap();
    assert_eq!(v.as_array().unwrap().len(), 1);
    let run_files: Vec<String> = std::fs::read_dir(&out_dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned()))
        .filter(|f| f.ends_with(".jsonl"))
        .collect();
    let text = std::fs::read_to_string(out_dir.join(&run_files[0])).unwrap();
    let recs = json::parse_jsonl(&text).unwrap();
    assert!(recs.iter().any(|r| r.get("kind").and_then(|k| k.as_str()) == Some("config")));
    assert!(recs.iter().filter(|r| r.get("kind").and_then(|k| k.as_str()) == Some("eval")).count() >= 2);
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn lm_pipeline_trains_and_reports_ppl() {
    let engine = engine_or_skip!();
    let cfg = TrainConfig {
        model: "tiny-lm".into(),
        sampler: "quadratic".into(),
        m: 4,
        epochs: 1,
        train_size: 2_000,
        valid_size: 600,
        eval_batches: 5,
        max_steps_per_epoch: 40,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let mut sink = MetricsSink::memory("lm-int");
    let res = trainer.train(&mut sink).unwrap();
    assert!(res.steps == 40);
    for p in &res.curve {
        assert!(p.loss.is_finite() && p.ppl().is_finite());
    }
    assert!(res.final_loss < res.curve[0].loss, "{:?}", res.curve);
}

#[test]
fn trainer_phase_times_cover_all_phases() {
    let engine = engine_or_skip!();
    let cfg = TrainConfig {
        model: "tiny".into(),
        sampler: "quadratic".into(),
        m: 4,
        epochs: 1,
        train_size: 320,
        valid_size: 160,
        eval_batches: 2,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let mut sink = MetricsSink::memory("phases");
    trainer.train(&mut sink).unwrap();
    let report = trainer.phases.report();
    for phase in ["encode", "sample", "step", "update", "eval"] {
        assert!(report.contains(phase), "missing phase {phase} in:\n{report}");
    }
}

#[test]
fn abs_softmax_model_trains_with_quadratic() {
    let engine = engine_or_skip!();
    let cfg = TrainConfig {
        model: "tiny-abs".into(),
        sampler: "quadratic".into(),
        m: 4,
        epochs: 2,
        train_size: 640,
        valid_size: 160,
        eval_batches: 5,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&engine, cfg).unwrap();
    let mut sink = MetricsSink::memory("abs");
    let res = trainer.train(&mut sink).unwrap();
    assert!(res.final_loss < res.curve[0].loss, "{:?}", res.curve);
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let err = Engine::new(Path::new("/nonexistent-kss")).err().expect("must fail");
    assert!(err.to_string().contains("manifest"), "{err:#}");
}

#[test]
fn corrupt_manifest_is_clean_error() {
    let dir = std::env::temp_dir().join(format!("kss-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = Engine::new(&dir).err().expect("must fail");
    assert!(format!("{err:#}").contains("pars"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_referencing_missing_hlo_fails_at_compile_time() {
    let Some(real) = artifacts_dir() else {
        eprintln!("artifacts not built; skipping");
        return;
    };
    // copy the manifest to an empty dir: executables can't be found
    let dir = std::env::temp_dir().join(format!("kss-missing-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(real.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let engine = Engine::new(&dir).unwrap(); // lazy compile: ok so far
    let spec = engine.manifest().model("tiny").unwrap().clone();
    let err = engine.executable(&spec.op("encode").unwrap().file).err().expect("must fail");
    assert!(format!("{err:#}").contains("parsing HLO"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_model_and_sampler_errors() {
    let engine = engine_or_skip!();
    let bad_model = TrainConfig { model: "nope".into(), ..Default::default() };
    assert!(Trainer::new(&engine, bad_model).is_err());
    let bad_sampler =
        TrainConfig { model: "tiny".into(), sampler: "nope".into(), ..Default::default() };
    let err = Trainer::new(&engine, bad_sampler).err().expect("must fail");
    assert!(err.to_string().contains("unknown sampler"), "{err}");
}

#[test]
fn bigram_on_recsys_dataset_is_clean_error() {
    let engine = engine_or_skip!();
    let cfg = TrainConfig {
        model: "tiny".into(),
        sampler: "bigram".into(),
        ..Default::default()
    };
    let err = Trainer::new(&engine, cfg).err().expect("must fail");
    assert!(err.to_string().contains("pair counts"), "{err}");
}

#[test]
fn manifest_loads_every_declared_artifact() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("artifacts not built; skipping");
        return;
    };
    let man = Manifest::load(&dir).unwrap();
    for (name, model) in &man.models {
        for (op_name, op) in &model.ops {
            let path = man.artifact_path(&op.file);
            assert!(path.exists(), "{name}/{op_name} missing: {path:?}");
            let head = std::fs::read_to_string(&path).unwrap();
            assert!(head.starts_with("HloModule"), "{name}/{op_name} is not HLO text");
        }
        for (m, op) in &model.train_sampled {
            assert!(man.artifact_path(&op.file).exists(), "{name}/train_sampled m={m}");
        }
    }
}
