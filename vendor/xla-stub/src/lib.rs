//! Offline stub for the subset of the `xla` (PJRT bindings) crate the
//! runtime layer uses.
//!
//! The build image carries no XLA shared libraries, so this path dependency
//! keeps the crate compiling and the pure-host paths fully functional:
//!
//! * [`Literal`] is a complete host-side implementation (shape + element
//!   type + row-major bytes, plus tuples) — the tensor round-trip tests and
//!   every sampler/coordinator path that never executes a device op work
//!   unchanged;
//! * [`PjRtClient::compile`] and [`PjRtLoadedExecutable::execute`] return a
//!   clear "PJRT unavailable offline" error. Training against real
//!   artifacts requires swapping the real `xla` crate back in at the
//!   workspace manifest — no call sites change.
//!
//! Everything that needs artifacts already skips cleanly when
//! `artifacts/manifest.json` is absent, so `cargo test` is green against
//! this stub on a fresh checkout.

use std::fmt;
use std::path::Path;

/// Stub error type (mirrors `xla::Error` far enough for `?` + context).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the manifest's artifacts can mention. Only `F32`/`S32` are
/// constructible host-side; the rest exist so match arms over foreign
/// literals stay honest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
    Bf16,
}

impl ElementType {
    /// Size of one element in bytes (0 for sub-byte/unsupported packing).
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Native Rust types a [`Literal`] can be copied out into.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_ne_slice(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne_slice(bytes: &[u8]) -> f32 {
        f32::from_ne_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne_slice(bytes: &[u8]) -> i32 {
        i32::from_ne_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Array shape: element type + dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal: a typed row-major array or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    Array { ty: ElementType, dims: Vec<i64>, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from raw row-major bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        let expect = elems * ty.byte_size();
        if data.len() != expect {
            return Err(Error::new(format!(
                "literal data is {} bytes, shape {dims:?} of {ty:?} needs {expect}"
            )));
        }
        Ok(Literal::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    /// The array shape, or an error for tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => {
                Ok(ArrayShape { ty: *ty, dims: dims.clone() })
            }
            Literal::Tuple(_) => Err(Error::new("literal is a tuple, not an array")),
        }
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return Err(Error::new(format!(
                        "literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                Ok(data
                    .chunks_exact(T::TY.byte_size())
                    .map(T::from_ne_slice)
                    .collect())
            }
            Literal::Tuple(_) => Err(Error::new("literal is a tuple, not an array")),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            Literal::Array { .. } => Err(Error::new("literal is an array, not a tuple")),
        }
    }
}

/// Parsed HLO text module (stored verbatim; the stub cannot compile it).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. Validates the header so corrupt files
    /// error here rather than at (stubbed-out) compile time.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path:?}: {e}")))?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(Error::new(format!("{path:?} is not HLO text")));
        }
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable. Never constructible through the stub client, but
/// the type (and its `execute` signature) keep the runtime layer compiling.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(OFFLINE_MSG))
    }
}

/// A device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(OFFLINE_MSG))
    }
}

const OFFLINE_MSG: &str =
    "PJRT is unavailable in the offline xla stub; point the workspace \
     dependency at the real `xla` crate to execute artifacts";

/// PJRT client (stub: creation succeeds so manifest-only workflows run;
/// compilation reports the offline limitation).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(OFFLINE_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = vec![1.5, -2.0, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn literal_size_validation() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 3]).is_err()
        );
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let t = Literal::Tuple(vec![a.clone()]);
        assert_eq!(t.to_tuple().unwrap(), vec![a]);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn compile_reports_offline() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
