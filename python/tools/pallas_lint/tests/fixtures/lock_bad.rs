// pallas-lint fixture — MUST trip LOCK three ways: a self-deadlock, a
// lock taken under a pinned snapshot binding, and an ordering cycle
// across two functions.

use std::sync::Mutex;

pub struct S {
    queue: Mutex<Vec<u32>>,
    state: Mutex<u32>,
    a: Mutex<u32>,
    b: Mutex<u32>,
}

pub struct Reader;
impl Reader {
    pub fn pinned(&self) -> u64 {
        0
    }
}

impl S {
    /// Self-deadlock: std::sync::Mutex is not reentrant.
    pub fn double_lock(&self) {
        let first = self.queue.lock().unwrap();
        let second = self.queue.lock().unwrap();
        drop(second);
        drop(first);
    }

    /// Lock acquired while a pinned snapshot generation is held.
    pub fn lock_under_pin(&self, reader: &Reader) {
        let snap = reader.pinned();
        let g = self.state.lock().unwrap();
        drop(g);
        let _ = snap;
    }

    /// With order_ba below: a -> b and b -> a, an ordering cycle.
    pub fn order_ab(&self) {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        drop(gb);
        drop(ga);
    }

    pub fn order_ba(&self) {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        drop(ga);
        drop(gb);
    }
}
