//! Host-side tensors and conversion to/from XLA literals.
//!
//! The coordinator keeps parameters and batch data as [`Tensor`]s and
//! converts them to `xla::Literal`s at the execute boundary. Only the two
//! dtypes the models use (f32, i32) are supported; everything is row-major.

use anyhow::{anyhow, bail, Context, Result};

/// A host tensor: shape + row-major data.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32s(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32s(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor::I32 { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is {} not f32", self.dtype_name())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is {} not i32", self.dtype_name())),
        }
    }

    /// First element as f32 (scalar outputs like the loss).
    pub fn scalar(&self) -> Result<f32> {
        Ok(self.as_f32()?.first().copied().ok_or_else(|| anyhow!("empty tensor"))?)
    }

    /// Consume the tensor into its f32 buffer (no copy). The training
    /// pipeline uses this in both directions: artifact outputs become owned
    /// sampling inputs for a background stage, and staging tensors give
    /// their allocation back to the step scratch after execute.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            t => Err(anyhow!("tensor is {} not f32", t.dtype_name())),
        }
    }

    /// Consume the tensor into its i32 buffer (no copy) — see
    /// [`Tensor::into_f32`].
    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            t => Err(anyhow!("tensor is {} not i32", t.dtype_name())),
        }
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32 { shape, data } => {
                // SAFETY: `data` is a live Vec<f32> borrowed for this call,
                // so the pointer is valid for `data.len() * 4` bytes
                // (size_of::<f32>() == 4, no padding between elements);
                // u8 has alignment 1 and every byte pattern is a valid u8.
                // The borrow of `data` outlives `bytes` (consumed below).
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
                    .context("creating f32 literal")?
            }
            Tensor::I32 { shape, data } => {
                // SAFETY: same invariants as the F32 arm with
                // size_of::<i32>() == 4 — pointer valid for len * 4 bytes,
                // u8 is align-1 and any-bit-pattern, borrow outlives `bytes`.
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
                    .context("creating i32 literal")?
            }
        };
        Ok(lit)
    }

    /// Convert an XLA literal back to a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::f32s(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32_and_scalar() {
        let t = Tensor::i32s(&[4], vec![7, -1, 0, 3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = Tensor::scalar_f32(2.5);
        let back = Tensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(back.scalar().unwrap(), 2.5);
        assert_eq!(back.shape(), &[] as &[usize]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::f32s(&[2, 2], vec![1.0]);
    }

    #[test]
    fn dtype_errors() {
        let t = Tensor::i32s(&[1], vec![1]);
        assert!(t.as_f32().is_err());
        assert!(t.scalar().is_err());
        let f = Tensor::zeros_f32(&[3]);
        assert!(f.as_i32().is_err());
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn into_buffers_reclaim_without_copy() {
        let mut v = Vec::with_capacity(64);
        v.extend_from_slice(&[1.0f32, 2.0, 3.0]);
        let ptr = v.as_ptr();
        let t = Tensor::f32s(&[3], v);
        let back = t.into_f32().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0]);
        assert_eq!(back.as_ptr(), ptr, "reclaim must reuse the allocation");
        assert!(back.capacity() >= 64);
        assert!(Tensor::i32s(&[1], vec![1]).into_f32().is_err());
        assert_eq!(Tensor::i32s(&[2], vec![4, 5]).into_i32().unwrap(), vec![4, 5]);
        assert!(Tensor::zeros_f32(&[1]).into_i32().is_err());
    }
}
