//! The mutable tier of the streaming vocabulary: a small flat sampler
//! over recently inserted classes, plus the tombstone set that masks
//! retired arena classes.
//!
//! # Memtable
//!
//! [`Memtable`] is the LSM "memtable" of the vocab subsystem: classes
//! inserted since the last compaction live here as plain embedding rows
//! with an **explicit slot ↔ id mapping** — slot `j` (the dense internal
//! index the CDF is built over) carries global class id `ids[j]`, and
//! `index` maps ids back to slots. Nothing in the draw path ever assumes
//! global ids are dense `0..C`; the id space may have holes from retired
//! classes and fresh inserts (the aliasing hazard [`crate::util::rng::Cdf`]
//! documents — see [`crate::util::rng::IdCdf`] for the standalone
//! primitive).
//!
//! Per example the tier's weights are the kernel scores `K(h, w_j)`
//! recomputed from the current rows ("mass-refreshed on update": an
//! embedding update is immediately reflected in the next draw — there is
//! no cached mass to invalidate). The draw itself is the flat-CDF
//! procedure every oracle sampler uses: prefix sums via
//! [`crate::ops::fill_cum_into`], one uniform, one `partition_point`.
//!
//! # Tombstones
//!
//! [`TombstoneSet`] records retired **arena slots** (sorted, binary
//! searched on the draw path) together with a frozen copy of each
//! retired row. The quadratic kernel is `αo²+1 ≥ 1`, so a retired class
//! can never be silenced through its embedding — instead its kernel mass
//! is *subtracted* from the arena tier's partition total (the frozen rows
//! make that subtraction exact: updates to tombstoned classes are dropped
//! by the streaming layer, so the frozen copy always equals the row the
//! arena still holds) and draws that land on a tombstoned slot are
//! rejected and redrawn (see `draw_from_tiers` in
//! [`crate::vocab::streaming`]).

use crate::ops;
use crate::sampler::kernel::FeatureMap;
use crate::util::rng::{sample_cum, Rng};
use anyhow::Result;
use std::collections::HashMap;

/// The mutable memtable tier: recently inserted classes as flat rows with
/// an explicit slot ↔ global-id mapping (see module docs).
#[derive(Clone, Debug, Default)]
pub struct Memtable {
    d: usize,
    /// slot → global class id (the draw path returns `ids[slot]`).
    ids: Vec<u32>,
    /// Embedding rows, slot-major (`len() × d`).
    rows: Vec<f32>,
    /// global class id → slot (kept exactly inverse to `ids`).
    index: HashMap<u32, usize>,
}

impl Memtable {
    pub fn new(d: usize) -> Memtable {
        Memtable { d, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// slot → global id map (slot order is the CDF order).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// The flat slot-major row panel (for compaction gathers).
    pub fn rows(&self) -> &[f32] {
        &self.rows
    }

    pub fn contains(&self, id: u32) -> bool {
        self.index.contains_key(&id)
    }

    pub fn slot_of(&self, id: u32) -> Option<usize> {
        self.index.get(&id).copied()
    }

    pub fn id_at(&self, slot: usize) -> u32 {
        self.ids[slot]
    }

    pub fn row(&self, slot: usize) -> &[f32] {
        &self.rows[slot * self.d..(slot + 1) * self.d]
    }

    /// Insert a new class. Errors on a duplicate id or a wrong-sized row —
    /// the streaming layer checks liveness across *both* tiers first.
    pub fn insert(&mut self, id: u32, row: &[f32]) -> Result<()> {
        anyhow::ensure!(row.len() == self.d, "row has {} floats, d = {}", row.len(), self.d);
        anyhow::ensure!(!self.contains(id), "class {id} already in the memtable");
        self.index.insert(id, self.ids.len());
        self.ids.push(id);
        self.rows.extend_from_slice(row);
        Ok(())
    }

    /// Remove a class (swap-remove: the last slot moves into the hole, the
    /// id map is patched — deterministic as a function of the op sequence).
    pub fn remove(&mut self, id: u32) -> bool {
        let Some(slot) = self.index.remove(&id) else {
            return false;
        };
        let last = self.ids.len() - 1;
        if slot != last {
            self.ids[slot] = self.ids[last];
            let (a, b) = self.rows.split_at_mut(last * self.d);
            a[slot * self.d..(slot + 1) * self.d].copy_from_slice(&b[..self.d]);
            self.index.insert(self.ids[slot], slot);
        }
        self.ids.pop();
        self.rows.truncate(last * self.d);
        true
    }

    /// Replace a class's row; the next draw sees the new mass immediately.
    pub fn update_row(&mut self, id: u32, row: &[f32]) -> bool {
        debug_assert_eq!(row.len(), self.d);
        let Some(&slot) = self.index.get(&id) else {
            return false;
        };
        self.rows[slot * self.d..(slot + 1) * self.d].copy_from_slice(row);
        true
    }

    /// Drop every entry (compaction folded them into the arena).
    pub fn clear(&mut self) {
        self.ids.clear();
        self.rows.clear();
        self.index.clear();
    }

    /// Per-example kernel weights `K(h, w_j)` per slot, into `out`
    /// (resized). Same numerators a kernel tree's leaf pass computes, so
    /// the composite q algebra matches a single tree over the union.
    pub fn weights_into<M: FeatureMap>(&self, map: &M, h: &[f32], out: &mut Vec<f64>) {
        out.resize(self.len(), 0.0);
        if !self.is_empty() {
            map.kernel_many(h, &self.rows, out);
        }
    }

    /// Draw one slot from prepared per-example cumulative weights (the
    /// flat-CDF draw; `cum`/`total` come from [`Memtable::weights_into`] +
    /// [`crate::ops::fill_cum_into`]). Returns `(slot, global id)`.
    pub fn draw_prepared(&self, cum: &[f64], total: f64, rng: &mut Rng) -> (usize, u32) {
        debug_assert_eq!(cum.len(), self.len());
        debug_assert!(total > 0.0 && total.is_finite());
        let slot = sample_cum(cum, total, rng);
        (slot, self.ids[slot])
    }
}

/// Retired arena classes: sorted slots + frozen rows (see module docs).
#[derive(Clone, Debug, Default)]
pub struct TombstoneSet {
    d: usize,
    /// Retired arena slots, sorted ascending (draw path binary-searches).
    slots: Vec<u32>,
    /// Frozen embedding rows, same order as `slots` (`len() × d`).
    rows: Vec<f32>,
}

impl TombstoneSet {
    pub fn new(d: usize) -> TombstoneSet {
        TombstoneSet { d, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn slots(&self) -> &[u32] {
        &self.slots
    }

    /// The frozen row panel (the mass the arena tier must exclude).
    pub fn rows(&self) -> &[f32] {
        &self.rows
    }

    pub fn contains(&self, slot: u32) -> bool {
        self.slots.binary_search(&slot).is_ok()
    }

    /// Tombstone an arena slot, freezing its current row. Returns false if
    /// already tombstoned.
    pub fn insert(&mut self, slot: u32, row: &[f32]) -> bool {
        debug_assert_eq!(row.len(), self.d);
        let pos = match self.slots.binary_search(&slot) {
            Ok(_) => return false,
            Err(pos) => pos,
        };
        self.slots.insert(pos, slot);
        // keep rows in slot order so the panel mirrors `slots`
        let at = pos * self.d;
        self.rows.splice(at..at, row.iter().copied());
        true
    }

    pub fn clear(&mut self) {
        self.slots.clear();
        self.rows.clear();
    }

    /// Total kernel mass of the tombstoned rows for query `h` — the mass
    /// the arena tier subtracts from its partition total. `k_buf`/`cum_buf`
    /// are caller scratch (resized); the prefix-sum total keeps the
    /// reduction in the ops layer.
    pub fn mass<M: FeatureMap>(
        &self,
        map: &M,
        h: &[f32],
        k_buf: &mut Vec<f64>,
        cum_buf: &mut Vec<f64>,
    ) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        k_buf.resize(self.len(), 0.0);
        cum_buf.resize(self.len(), 0.0);
        map.kernel_many(h, &self.rows, k_buf);
        ops::fill_cum_into(k_buf, cum_buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;

    #[test]
    fn memtable_slot_id_mapping_survives_churn() {
        let d = 2;
        let mut mt = Memtable::new(d);
        // deliberately holey, non-dense, out-of-order global ids
        for (id, v) in [(90u32, 1.0f32), (3, 2.0), (17, 3.0), (1000, 4.0)] {
            mt.insert(id, &[v, -v]).unwrap();
        }
        assert!(mt.insert(17, &[0.0, 0.0]).is_err(), "duplicate id must error");
        assert_eq!(mt.len(), 4);
        assert!(mt.remove(3));
        assert!(!mt.remove(3), "double remove");
        assert_eq!(mt.len(), 3);
        // swap-remove moved 1000 into slot 1; the id map must follow
        for &id in &[90u32, 17, 1000] {
            let slot = mt.slot_of(id).unwrap();
            assert_eq!(mt.id_at(slot), id);
        }
        assert_eq!(mt.slot_of(3), None);
        // row contents track their id, not their slot
        let slot = mt.slot_of(1000).unwrap();
        assert_eq!(mt.row(slot), &[4.0, -4.0]);
        assert!(mt.update_row(1000, &[5.0, 5.0]));
        assert_eq!(mt.row(slot), &[5.0, 5.0]);
        assert!(!mt.update_row(3, &[9.0, 9.0]), "retired id must not alias");
    }

    #[test]
    fn memtable_draw_returns_global_ids_with_exact_q() {
        let d = 3;
        let map = QuadraticMap::new(d, 50.0);
        let mut mt = Memtable::new(d);
        let mut rng = Rng::new(7);
        let ids = [5u32, 900, 42, 77, 12345];
        for &id in &ids {
            let mut row = vec![0.0f32; d];
            rng.fill_normal(&mut row, 0.8);
            mt.insert(id, &row).unwrap();
        }
        let h = [0.4f32, -1.1, 0.6];
        let mut w = Vec::new();
        mt.weights_into(&map, &h, &mut w);
        let mut cum = vec![0.0; w.len()];
        let total = ops::fill_cum_into(&w, &mut cum);
        assert!(total > 0.0);
        // empirical: every drawn id is a real member, and each slot's
        // weight matches the kernel recomputed from its row
        for _ in 0..2000 {
            let (slot, id) = mt.draw_prepared(&cum, total, &mut rng);
            assert!(ids.contains(&id), "alien id {id}");
            assert_eq!(mt.id_at(slot), id);
            let k = map.kernel(&h, mt.row(slot));
            assert_eq!(k, w[slot], "weight must be the kernel, bitwise");
        }
    }

    #[test]
    fn tombstone_mass_matches_frozen_rows() {
        let d = 2;
        let map = QuadraticMap::new(d, 100.0);
        let mut ts = TombstoneSet::new(d);
        assert_eq!(ts.mass(&map, &[1.0, 1.0], &mut Vec::new(), &mut Vec::new()), 0.0);
        ts.insert(7, &[0.5, -0.5]);
        ts.insert(2, &[1.5, 0.25]);
        ts.insert(11, &[-0.75, 2.0]);
        assert!(!ts.insert(7, &[9.0, 9.0]), "re-tombstone is a no-op");
        assert_eq!(ts.slots(), &[2, 7, 11], "slots stay sorted");
        assert!(ts.contains(7) && !ts.contains(8));
        let h = [0.3f32, 0.9];
        let mut k = Vec::new();
        let mut cum = Vec::new();
        let got = ts.mass(&map, &h, &mut k, &mut cum);
        let want: f64 = [[1.5f32, 0.25], [0.5, -0.5], [-0.75, 2.0]]
            .iter()
            .map(|r| map.kernel(&h, r))
            .sum();
        assert!((got - want).abs() <= 1e-12 * want, "{got} vs {want}");
    }
}
