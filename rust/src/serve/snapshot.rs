//! Epoch snapshots: immutable kernel-tree generations behind an atomic
//! publish point, plus the double-buffered writer that produces them.
//!
//! # Reader protocol
//!
//! [`SnapshotStore`] holds the current generation as an `Arc<T>` guarded by
//! a mutex, next to an `AtomicU64` generation counter that is the *only*
//! thing the steady-state read path touches. Each reader thread owns a
//! [`SnapshotReader`], which caches `(generation, Arc)`; `current()` is one
//! relaxed-acquire atomic load and a compare — wait-free — and only when
//! the counter moved does the reader take the mutex for the microseconds an
//! `Arc::clone` costs. The writer holds that same mutex only for the
//! pointer swap itself, never while building the next generation, so
//! publishing G+1 stalls readers for at most one clone/swap critical
//! section (the serve bench measures it). A reader that keeps using its
//! cached `Arc` sees generation G bit-for-bit forever: snapshots are
//! immutable by construction.
//!
//! # Writer protocol (double-buffered arenas, no full rebuild)
//!
//! [`TreePublisher`] owns the mutable *shadow* tree the trainer updates.
//! Publishing does not rebuild and, in steady state, does not copy either:
//! the publisher retains a handle to each published generation and, once
//! readers have released generation G−k (its `Arc` strong count drops to
//! 1), reclaims that arena and **replays** the logged update batches to
//! fast-forward it from G−k to the new generation — each batch is applied
//! once to the shadow and once more during a later replay, the classic
//! left-right scheme. Only when no retired arena has been released yet
//! (cold start, or readers pinning old generations) does it fall back to a
//! flat `clone()` of the shadow (a memcpy of the arena — still no φ
//! recomputation). [`PublishStats`] counts which path ran.

use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::sampler::kernel::tree::KernelTreeSampler;
use crate::sampler::kernel::FeatureMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One immutable published generation of a kernel tree.
pub struct TreeSnapshot<M: FeatureMap> {
    /// Monotonic generation number (0 = the initial publish).
    pub generation: u64,
    /// The frozen tree. Only `&self` methods are reachable through the
    /// `Arc`, and serving code goes through [`KernelTreeSampler::view`].
    pub tree: KernelTreeSampler<M>,
}

/// Atomic publish point for `Arc<T>` generations (see module docs for the
/// reader/writer protocol). Generic so tests can exercise it with plain
/// values; the serve layer instantiates it with [`TreeSnapshot`].
pub struct SnapshotStore<T> {
    /// (generation, current). The mutex is held only for clone/swap.
    current: Mutex<(u64, Arc<T>)>,
    /// Fast-path generation mirror: readers poll this without locking.
    gen: AtomicU64,
}

impl<T> SnapshotStore<T> {
    pub fn new(initial: T) -> SnapshotStore<T> {
        SnapshotStore { current: Mutex::new((0, Arc::new(initial))), gen: AtomicU64::new(0) }
    }

    /// Generation of the currently published snapshot.
    pub fn generation(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    /// Clone a handle to the current snapshot (one short lock).
    pub fn load(&self) -> (u64, Arc<T>) {
        let guard = self.current.lock().expect("snapshot store poisoned");
        (guard.0, guard.1.clone())
    }

    /// Swap in the next generation and return its number. The lock is held
    /// only for the swap — building `next` happened outside.
    pub fn publish(&self, next: Arc<T>) -> u64 {
        let mut guard = self.current.lock().expect("snapshot store poisoned");
        let g = guard.0 + 1;
        *guard = (g, next);
        // release-store after the swap so a reader that observes the new
        // counter always finds the new Arc under the mutex
        self.gen.store(g, Ordering::Release);
        g
    }
}

/// Per-reader-thread cache over a [`SnapshotStore`]: `current()` is
/// wait-free (one atomic load) until a publish happens, then refreshes with
/// one short lock. Holding on to the returned `Arc` pins that generation.
pub struct SnapshotReader<T> {
    store: Arc<SnapshotStore<T>>,
    cached: Arc<T>,
    cached_gen: u64,
}

impl<T> SnapshotReader<T> {
    pub fn new(store: Arc<SnapshotStore<T>>) -> SnapshotReader<T> {
        let (cached_gen, cached) = store.load();
        SnapshotReader { store, cached, cached_gen }
    }

    /// Generation of the cached snapshot.
    pub fn generation(&self) -> u64 {
        self.cached_gen
    }

    /// The freshest snapshot: refreshes the cache iff the store's
    /// generation counter moved since the last call.
    pub fn current(&mut self) -> &Arc<T> {
        if self.store.generation() != self.cached_gen {
            let (g, arc) = self.store.load();
            self.cached_gen = g;
            self.cached = arc;
        }
        &self.cached
    }

    /// The cached snapshot without checking for a newer generation —
    /// readers mid-request use this so one request never mixes generations.
    pub fn pinned(&self) -> &Arc<T> {
        &self.cached
    }
}

impl<T> Clone for SnapshotReader<T> {
    fn clone(&self) -> Self {
        SnapshotReader {
            store: self.store.clone(),
            cached: self.cached.clone(),
            cached_gen: self.cached_gen,
        }
    }
}

/// Publish-path accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct PublishStats {
    /// Generations published (excluding the initial one).
    pub publishes: u64,
    /// Publishes that reused a reclaimed retired arena via replay.
    pub reclaimed: u64,
    /// Publishes that fell back to a flat clone of the shadow.
    pub copied: u64,
    /// Update batches replayed onto reclaimed arenas.
    pub replayed_batches: u64,
    /// Compaction publishes: the shadow was wholesale replaced by a
    /// rebuilt tree (streaming-vocab memtable fold, see `crate::vocab`).
    pub compactions: u64,
    /// Retired arena handles discarded because they predate the latest
    /// compaction barrier and can never be fast-forwarded again.
    pub discarded_stale: u64,
}

/// Timing report of one publish.
#[derive(Clone, Copy, Debug)]
pub struct PublishReport {
    pub generation: u64,
    /// Seconds spent building the next snapshot (replay or clone) —
    /// off the reader path.
    pub build_s: f64,
    /// Seconds the store's swap lock was held — the only interval a
    /// refreshing reader can contend with.
    pub swap_s: f64,
    /// Whether the build reclaimed a retired arena (vs cloning).
    pub reclaimed: bool,
}

/// Shared telemetry cells for one publisher. Sharded serve sets register
/// every shard's cells under the same names, so exports see fleet-wide
/// series (counters sum, histograms merge — see
/// [`MetricsRegistry::snapshot`]).
#[derive(Clone, Default)]
pub struct PublishObs {
    /// Publish→visible lag per publish: build (replay or clone) + swap.
    lag: Arc<Histogram>,
    /// Swap-lock hold time alone — the only window a refreshing reader
    /// can contend with.
    swap: Arc<Histogram>,
    /// Publishes that fast-forwarded a reclaimed arena by replay.
    replayed: Arc<Counter>,
    /// Publishes that fell back to a flat clone of the shadow.
    cloned: Arc<Counter>,
    /// Retired-queue overflows: a pinned old generation forced the
    /// publisher to drop its oldest reclaim handle (sustained growth
    /// means a stuck reader is degrading publishes toward clones).
    pinned_stalls: Arc<Counter>,
    /// Compaction publishes (replay-log barrier records).
    compactions: Arc<Counter>,
    /// Retired handles discarded at a compaction barrier.
    stale_arenas: Arc<Counter>,
}

impl PublishObs {
    /// Bind every cell to `reg` under the stable `kss_publish_*` names.
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_histogram(
            "kss_publish_lag_seconds",
            "seconds",
            "serve",
            "publish-to-visible lag (build + swap) per generation",
            Arc::clone(&self.lag),
        );
        reg.register_histogram(
            "kss_publish_swap_seconds",
            "seconds",
            "serve",
            "swap-lock hold time per publish",
            Arc::clone(&self.swap),
        );
        reg.register_counter(
            "kss_publish_replayed_total",
            "publishes",
            "serve",
            "publishes served by replaying a reclaimed arena",
            Arc::clone(&self.replayed),
        );
        reg.register_counter(
            "kss_publish_cloned_total",
            "publishes",
            "serve",
            "publishes that fell back to cloning the shadow arena",
            Arc::clone(&self.cloned),
        );
        reg.register_counter(
            "kss_publish_pinned_stall_total",
            "events",
            "serve",
            "reclaim handles dropped because readers pinned old generations",
            Arc::clone(&self.pinned_stalls),
        );
        reg.register_counter(
            "kss_publish_compact_total",
            "publishes",
            "serve",
            "compaction publishes (shadow replaced by a rebuilt tree)",
            Arc::clone(&self.compactions),
        );
        reg.register_counter(
            "kss_publish_stale_arena_total",
            "events",
            "serve",
            "retired arenas discarded at a compaction barrier",
            Arc::clone(&self.stale_arenas),
        );
    }

    /// Publishes recorded so far (= lag-histogram count).
    pub fn publishes(&self) -> u64 {
        self.lag.count()
    }

    pub fn replayed_total(&self) -> u64 {
        self.replayed.get()
    }

    pub fn cloned_total(&self) -> u64 {
        self.cloned.get()
    }

    pub fn pinned_stall_total(&self) -> u64 {
        self.pinned_stalls.get()
    }

    pub fn compact_total(&self) -> u64 {
        self.compactions.get()
    }

    pub fn stale_arena_total(&self) -> u64 {
        self.stale_arenas.get()
    }
}

/// One replay-log record. `Update` is the fast-forward unit; `Compact` is
/// a **barrier**: the shadow was wholesale replaced by a rebuilt tree (a
/// streaming-vocab memtable fold — possibly a different class count and
/// arena shape), so no arena published before the barrier can ever be
/// fast-forwarded across it. Barrier handling happens at reclaim time
/// (pre-barrier handles are discarded), so the replay loop only ever sees
/// `Compact` records at or below the reclaimed generation.
enum LogRecord {
    Update {
        /// Generation this batch produced when applied to the shadow.
        gen: u64,
        classes: Vec<usize>,
        rows: Vec<f32>,
    },
    Compact { gen: u64 },
}

impl LogRecord {
    fn gen(&self) -> u64 {
        match self {
            LogRecord::Update { gen, .. } | LogRecord::Compact { gen } => *gen,
        }
    }
}

/// Retired generations the publisher still holds a handle to. Bounded: if
/// readers pin more generations than this, the oldest handles are dropped
/// (readers keep them alive; the publisher just loses the chance to
/// reclaim those arenas and falls back to cloning).
const MAX_RETIRED: usize = 6;

/// Double-buffered snapshot writer for one kernel tree (see module docs).
pub struct TreePublisher<M: FeatureMap + Clone> {
    store: Arc<SnapshotStore<TreeSnapshot<M>>>,
    /// The writer's working tree, always at the latest generation.
    shadow: KernelTreeSampler<M>,
    shadow_gen: u64,
    /// Published generations awaiting reclamation (oldest first).
    retired: VecDeque<Arc<TreeSnapshot<M>>>,
    /// Replay records newer than the oldest retired generation — exactly
    /// what a reclaimed arena may need to fast-forward.
    log: VecDeque<LogRecord>,
    /// Generation of the most recent compaction publish (0 = never).
    /// Retired arenas older than this are permanently non-reclaimable.
    last_compact_gen: u64,
    pub stats: PublishStats,
    /// Telemetry cells (see [`PublishObs`]).
    obs: PublishObs,
}

impl<M: FeatureMap + Clone> TreePublisher<M> {
    /// Wrap a tree and publish it as generation 0.
    pub fn new(tree: KernelTreeSampler<M>) -> TreePublisher<M> {
        let snap = Arc::new(TreeSnapshot { generation: 0, tree: tree.clone() });
        let store = Arc::new(SnapshotStore::new_with_arc(snap.clone()));
        let mut retired = VecDeque::new();
        retired.push_back(snap);
        TreePublisher {
            store,
            shadow: tree,
            shadow_gen: 0,
            retired,
            log: VecDeque::new(),
            last_compact_gen: 0,
            stats: PublishStats::default(),
            obs: PublishObs::default(),
        }
    }

    /// The publish point readers subscribe to.
    pub fn store(&self) -> Arc<SnapshotStore<TreeSnapshot<M>>> {
        self.store.clone()
    }

    /// Telemetry cells (register into a registry via
    /// [`PublishObs::register_into`]).
    pub fn obs(&self) -> &PublishObs {
        &self.obs
    }

    /// The writer's working tree (read access, e.g. for seeding checks).
    pub fn shadow(&self) -> &KernelTreeSampler<M> {
        &self.shadow
    }

    /// Apply one update batch to the shadow and publish the result as the
    /// next generation. `classes` sorted + deduplicated, `rows` the flat
    /// (len·d) buffer of new embeddings — the same contract as
    /// [`KernelTreeSampler::update_many`].
    pub fn update_and_publish(&mut self, classes: &[usize], rows: &[f32]) -> PublishReport {
        let t_build = Instant::now();
        self.shadow.update_many(classes, rows);
        self.shadow_gen += 1;
        self.log.push_back(LogRecord::Update {
            gen: self.shadow_gen,
            classes: classes.to_vec(),
            rows: rows.to_vec(),
        });
        self.discard_stale_retired();

        // Reclaim before the swap: the store still points at the previous
        // generation, whose Arc count is ≥ 2 (store + retired), so the live
        // snapshot can never be unwrapped here. Scan the whole retired
        // queue — a single slow reader pinning an old generation must not
        // block reclamation of the free arenas behind it (head-of-line
        // blocking would force a full clone per publish). Of several free
        // arenas, keep the newest (fewest batches to replay), drop the
        // rest; log trimming stays keyed off the true front, so every
        // arena still in the queue remains replay-coverable.
        let mut reclaimed: Option<TreeSnapshot<M>> = None;
        let mut i = 0;
        while i < self.retired.len() {
            if Arc::strong_count(&self.retired[i]) != 1 {
                i += 1;
                continue;
            }
            let arc = self.retired.remove(i).expect("index checked");
            match Arc::try_unwrap(arc) {
                // oldest→newest scan: a later free arena replaces an
                // earlier one, which is simply dropped
                Ok(snap) => reclaimed = Some(snap),
                Err(arc) => {
                    // a reader cloned between the count check and the
                    // unwrap; put it back and move on
                    self.retired.insert(i, arc);
                    i += 1;
                }
            }
        }
        let was_reclaimed = reclaimed.is_some();
        let next = match reclaimed {
            Some(mut snap) => {
                // fast-forward: replay every logged record newer than the
                // reclaimed generation (the log is trimmed below to always
                // cover the oldest retired generation). Compact records
                // cannot appear past the reclaimed generation — pre-barrier
                // handles were discarded above — so replay only ever
                // applies plain update batches.
                for rec in self.log.iter() {
                    match rec {
                        LogRecord::Update { gen, classes, rows } if *gen > snap.generation => {
                            snap.tree.update_many(classes, rows);
                            self.stats.replayed_batches += 1;
                        }
                        LogRecord::Compact { gen } => {
                            debug_assert!(
                                *gen <= snap.generation,
                                "replay crossed a compaction barrier (arena gen {}, barrier {})",
                                snap.generation,
                                gen
                            );
                        }
                        _ => {}
                    }
                }
                snap.generation = self.shadow_gen;
                self.stats.reclaimed += 1;
                snap
            }
            None => {
                self.stats.copied += 1;
                TreeSnapshot { generation: self.shadow_gen, tree: self.shadow.clone() }
            }
        };
        let build_s = t_build.elapsed().as_secs_f64();

        let (generation, swap_s) = self.publish_next(next);
        self.obs.lag.record(build_s + swap_s);
        self.obs.swap.record(swap_s);
        if was_reclaimed {
            self.obs.replayed.inc();
        } else {
            self.obs.cloned.inc();
        }

        PublishReport { generation, build_s, swap_s, reclaimed: was_reclaimed }
    }

    /// Replace the shadow wholesale with `tree` — a from-scratch rebuild
    /// over a possibly different class set (the streaming-vocab compactor
    /// folding its memtable into the arena, `crate::vocab`) — and publish
    /// it as the next generation. A `Compact` barrier record enters the
    /// replay log: arenas retired before the barrier have an incompatible
    /// shape and are discarded from the reclaim queue on this and every
    /// later publish (readers pinning them keep them alive — the publisher
    /// only forfeits the reclaim opportunity). The published snapshot is a
    /// clone of the new shadow: a fresh topology has no reclaimable arena
    /// yet by definition.
    pub fn compact_and_publish(&mut self, tree: KernelTreeSampler<M>) -> PublishReport {
        let t_build = Instant::now();
        self.shadow = tree;
        self.shadow_gen += 1;
        self.last_compact_gen = self.shadow_gen;
        self.log.push_back(LogRecord::Compact { gen: self.shadow_gen });
        self.discard_stale_retired();
        self.stats.compactions += 1;
        let next = TreeSnapshot { generation: self.shadow_gen, tree: self.shadow.clone() };
        let build_s = t_build.elapsed().as_secs_f64();

        let (generation, swap_s) = self.publish_next(next);
        self.obs.lag.record(build_s + swap_s);
        self.obs.swap.record(swap_s);
        self.obs.compactions.inc();

        PublishReport { generation, build_s, swap_s, reclaimed: false }
    }

    /// Drop retired handles that predate the latest compaction barrier:
    /// their arena shape can never be fast-forwarded across it, so keeping
    /// them only pins replay-log records forever. Readers holding those
    /// generations keep them alive through their own `Arc`s.
    fn discard_stale_retired(&mut self) {
        let barrier = self.last_compact_gen;
        if barrier == 0 {
            return;
        }
        let before = self.retired.len();
        self.retired.retain(|s| s.generation >= barrier);
        let dropped = (before - self.retired.len()) as u64;
        if dropped > 0 {
            self.stats.discarded_stale += dropped;
            self.obs.stale_arenas.add(dropped);
        }
    }

    /// Shared publish tail: swap the snapshot in, bound the retired queue,
    /// trim the replay log to what the oldest retired arena still needs.
    fn publish_next(&mut self, next: TreeSnapshot<M>) -> (u64, f64) {
        let arc = Arc::new(next);
        self.retired.push_back(arc.clone());
        let t_swap = Instant::now();
        let generation = self.store.publish(arc);
        let swap_s = t_swap.elapsed().as_secs_f64();
        debug_assert_eq!(generation, self.shadow_gen);
        self.stats.publishes += 1;

        // Bound the retired queue: beyond MAX_RETIRED we stop tracking the
        // oldest handles (their readers keep them alive; we lose only the
        // reclaim opportunity).
        while self.retired.len() > MAX_RETIRED {
            self.retired.pop_front();
            self.obs.pinned_stalls.inc();
        }
        // The log only needs records newer than the oldest retired
        // generation (the furthest-behind arena we could ever reclaim).
        let min_gen = self.retired.front().map(|s| s.generation).unwrap_or(self.shadow_gen);
        while self.log.front().is_some_and(|b| b.gen() <= min_gen) {
            self.log.pop_front();
        }
        (generation, swap_s)
    }
}

impl<T> SnapshotStore<T> {
    /// Construct directly from an `Arc` (publisher bootstrap keeps a
    /// retained handle to generation 0).
    fn new_with_arc(initial: Arc<T>) -> SnapshotStore<T> {
        SnapshotStore { current: Mutex::new((0, initial)), gen: AtomicU64::new(0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::{Sample, SampleInput, Sampler};
    use crate::util::rng::Rng;

    fn tree(n: usize, d: usize, seed: u64) -> (KernelTreeSampler<QuadraticMap>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let mut t = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        t.reset_embeddings(&emb, n, d);
        (t, emb)
    }

    fn draws(snap: &TreeSnapshot<QuadraticMap>, h: &[f32], seed: u64) -> (Vec<u32>, Vec<f64>) {
        let input = SampleInput { h: Some(h), ..Default::default() };
        let mut out = Sample::default();
        let mut rng = Rng::new(seed);
        snap.tree.sample(&input, 64, &mut rng, &mut out).unwrap();
        (out.classes, out.q)
    }

    #[test]
    fn held_generation_is_bit_identical_across_publishes() {
        let (t, _) = tree(40, 3, 1);
        let d = 3;
        let mut publisher = TreePublisher::new(t);
        let store = publisher.store();
        let h = vec![0.7f32, -0.3, 1.1];
        let (g0, pinned) = store.load();
        assert_eq!(g0, 0);
        let before = draws(&pinned, &h, 99);
        // publish several new generations while the reader holds gen 0
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let classes = vec![1usize, 7, 20];
            let mut rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut rows, 0.8);
            publisher.update_and_publish(&classes, &rows);
        }
        assert_eq!(store.generation(), 5);
        // the pinned snapshot must replay the identical stream, bit for bit
        let after = draws(&pinned, &h, 99);
        assert_eq!(before.0, after.0, "classes changed under a held snapshot");
        assert_eq!(before.1, after.1, "q changed under a held snapshot");
        // while a fresh load sees the updated distribution
        let (g5, fresh) = store.load();
        assert_eq!(g5, 5);
        assert_eq!(fresh.generation, 5);
        let now = draws(&fresh, &h, 99);
        assert_ne!(before.1, now.1, "new generation should differ");
    }

    #[test]
    fn reader_refreshes_only_on_generation_change() {
        let (t, _) = tree(16, 2, 3);
        let mut publisher = TreePublisher::new(t);
        let mut reader = SnapshotReader::new(publisher.store());
        assert_eq!(reader.current().generation, 0);
        let p0 = Arc::as_ptr(reader.pinned());
        assert_eq!(Arc::as_ptr(reader.current()), p0, "no publish -> same Arc");
        publisher.update_and_publish(&[3], &[0.5, -0.5]);
        assert_eq!(reader.generation(), 0, "pinned view stays until refreshed");
        assert_eq!(reader.current().generation, 1);
        assert_ne!(Arc::as_ptr(reader.pinned()), p0);
    }

    #[test]
    fn publisher_reclaims_released_arenas_and_replay_matches_shadow() {
        let (t, emb) = tree(48, 3, 5);
        let d = 3;
        let n = 48;
        // reference: a plain tree receiving the same updates directly
        let mut reference = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        reference.reset_embeddings(&emb, n, d);
        let mut publisher = TreePublisher::new(t);
        let mut reader = SnapshotReader::new(publisher.store());
        let mut rng = Rng::new(7);
        for step in 0..12 {
            let k = 1 + (step % 5);
            let mut classes: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut classes);
            classes.truncate(k);
            classes.sort_unstable();
            let mut rows = vec![0.0f32; k * d];
            rng.fill_normal(&mut rows, 0.7);
            reference.update_many(&classes, &rows);
            publisher.update_and_publish(&classes, &rows);
            // the reader tracks the head, releasing old generations so the
            // publisher's reclaim path actually runs
            reader.current();
        }
        let stats = publisher.stats;
        assert_eq!(stats.publishes, 12);
        assert!(stats.reclaimed > 0, "reclaim path never ran: {stats:?}");
        // telemetry mirrors the publish-path accounting: every publish
        // recorded a lag sample and chose exactly one build path
        let obs = publisher.obs();
        assert_eq!(obs.publishes(), 12);
        assert_eq!(obs.replayed_total(), stats.reclaimed);
        assert_eq!(obs.cloned_total(), stats.copied);
        assert_eq!(obs.replayed_total() + obs.cloned_total(), 12);
        // every published snapshot — reclaimed-and-replayed or cloned —
        // must match the straight-line reference exactly
        let (g, snap) = publisher.store().load();
        assert_eq!(g, 12);
        let h = vec![0.4f32, 0.9, -1.2];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        for c in [0u32, 11, 30, 47] {
            let a = snap.tree.prob(&input, c).unwrap();
            let b = reference.prob(&input, c).unwrap();
            assert!((a - b).abs() < 1e-12 * b.max(1e-12), "class {c}: {a} vs {b}");
        }
        assert!(snap.tree.max_drift() < 1e-9, "drift {}", snap.tree.max_drift());
    }

    #[test]
    fn pinned_old_generation_does_not_block_reclamation() {
        // head-of-line regression: one reader pins an early generation
        // forever; free arenas behind it must still be reclaimed (not
        // every publish degraded to a full clone), the pinned snapshot
        // stays bit-identical, and replay stays exact
        let (t, emb) = tree(32, 2, 13);
        let (n, d) = (32usize, 2usize);
        let mut reference = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, Some(4));
        reference.reset_embeddings(&emb, n, d);
        let mut publisher = TreePublisher::new(t);
        let store = publisher.store();
        let mut rng = Rng::new(17);
        let mut rows = vec![0.0f32; 2 * d];
        rng.fill_normal(&mut rows, 0.5);
        reference.update_many(&[0, 20], &rows);
        publisher.update_and_publish(&[0, 20], &rows);
        let (_, pinned) = store.load(); // hold generation 1 for the whole test
        let h = vec![0.8f32, -0.4];
        let before = draws(&pinned, &h, 7);
        let clones_before = publisher.stats.copied;
        for step in 0..8 {
            let classes = vec![step % n, 10 + step % 20];
            let mut classes: Vec<usize> = classes;
            classes.sort_unstable();
            classes.dedup();
            let mut rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut rows, 0.5);
            reference.update_many(&classes, &rows);
            publisher.update_and_publish(&classes, &rows);
        }
        assert!(
            publisher.stats.reclaimed >= 6,
            "pinned gen blocked reclamation: {:?}",
            publisher.stats
        );
        assert!(
            publisher.stats.copied <= clones_before + 2,
            "publishes degraded to clones: {:?}",
            publisher.stats
        );
        // pinned snapshot untouched; head replays the reference exactly
        let after = draws(&pinned, &h, 7);
        assert_eq!(before, after, "pinned generation changed");
        let (_, head) = store.load();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        for c in [0u32, 15, 31] {
            let a = head.tree.prob(&input, c).unwrap();
            let b = reference.prob(&input, c).unwrap();
            assert_eq!(a, b, "class {c}");
        }
        assert!(head.tree.max_drift() < 1e-9);
    }

    #[test]
    fn compaction_barrier_discards_stale_arenas_and_replay_resumes() {
        let (t, _) = tree(32, 3, 21);
        let (n2, d) = (40usize, 3usize);
        let mut publisher = TreePublisher::new(t);
        let mut reader = SnapshotReader::new(publisher.store());
        let mut rng = Rng::new(23);
        // a few pre-compaction generations; the reader releases them so
        // the retired queue holds free (reclaimable) pre-barrier arenas
        for _ in 0..3 {
            let mut rows = vec![0.0f32; 2 * d];
            rng.fill_normal(&mut rows, 0.6);
            publisher.update_and_publish(&[1, 30], &rows);
            reader.current();
        }
        // hold generation 3 across the compaction to prove barrier safety
        let pinned = reader.current().clone();
        let before = draws(&pinned, &[0.5, -0.2, 0.9], 31);

        // compact: replace the shadow with a *differently shaped* tree
        let mut emb2 = vec![0.0f32; n2 * d];
        rng.fill_normal(&mut emb2, 0.5);
        let mut rebuilt = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n2, Some(4));
        rebuilt.reset_embeddings(&emb2, n2, d);
        let mut reference = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n2, Some(4));
        reference.reset_embeddings(&emb2, n2, d);
        let report = publisher.compact_and_publish(rebuilt);
        assert_eq!(report.generation, 4);
        assert!(!report.reclaimed);
        assert_eq!(publisher.stats.compactions, 1);
        assert!(
            publisher.stats.discarded_stale >= 1,
            "pre-barrier arenas must be discarded: {:?}",
            publisher.stats
        );
        assert_eq!(publisher.obs().compact_total(), 1);
        assert_eq!(publisher.obs().stale_arena_total(), publisher.stats.discarded_stale);

        // post-barrier publishes must reclaim + replay again, and the head
        // must track a straight-line reference over the new class set
        let reclaimed_before = publisher.stats.reclaimed;
        for step in 0..8 {
            let classes = {
                let mut c = vec![step % n2, (7 + 3 * step) % n2];
                c.sort_unstable();
                c.dedup();
                c
            };
            let mut rows = vec![0.0f32; classes.len() * d];
            rng.fill_normal(&mut rows, 0.7);
            reference.update_many(&classes, &rows);
            publisher.update_and_publish(&classes, &rows);
            reader.current();
        }
        assert!(
            publisher.stats.reclaimed > reclaimed_before,
            "reclaim never resumed after the barrier: {:?}",
            publisher.stats
        );
        let (g, head) = publisher.store().load();
        assert_eq!(g, 12);
        assert_eq!(head.tree.num_classes(), n2);
        let h = vec![0.3f32, 0.8, -0.5];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        for c in [0u32, 17, 39] {
            let a = head.tree.prob(&input, c).unwrap();
            let b = reference.prob(&input, c).unwrap();
            assert_eq!(a, b, "class {c}");
        }
        // the pinned pre-compaction generation is untouched, bit for bit
        let after = draws(&pinned, &[0.5, -0.2, 0.9], 31);
        assert_eq!(before, after, "pinned pre-barrier generation changed");
    }

    #[test]
    fn concurrent_readers_sample_while_writer_publishes() {
        let (t, _) = tree(64, 3, 9);
        let d = 3;
        let mut publisher = TreePublisher::new(t);
        let store = publisher.store();
        let stop = std::sync::atomic::AtomicBool::new(false);
        std::thread::scope(|scope| {
            for worker in 0..4u64 {
                let store = store.clone();
                let stop = &stop;
                scope.spawn(move || {
                    let mut reader = SnapshotReader::new(store);
                    let mut rng = Rng::new(100 + worker);
                    let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let input = SampleInput { h: Some(&h), ..Default::default() };
                    let mut out = Sample::default();
                    let mut seen_gens = 0u64;
                    let mut last_gen = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let snap = reader.current().clone();
                        if snap.generation != last_gen {
                            last_gen = snap.generation;
                            seen_gens += 1;
                        }
                        snap.tree.sample(&input, 8, &mut rng, &mut out).unwrap();
                        for (&c, &q) in out.classes.iter().zip(&out.q) {
                            assert!((c as usize) < 64);
                            assert!(q > 0.0 && q.is_finite());
                        }
                    }
                    seen_gens
                });
            }
            let mut rng = Rng::new(11);
            for _ in 0..50 {
                let classes = vec![2usize, 17, 40, 63];
                let mut rows = vec![0.0f32; classes.len() * d];
                rng.fill_normal(&mut rows, 0.6);
                let report = publisher.update_and_publish(&classes, &rows);
                assert!(report.swap_s < 1.0, "swap took {}s", report.swap_s);
                std::thread::yield_now();
            }
            stop.store(true, Ordering::Relaxed);
        });
        assert_eq!(publisher.stats.publishes, 50);
        assert_eq!(store.generation(), 50);
    }
}
