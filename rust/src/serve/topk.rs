//! Top-k beam retrieval over published snapshots — the inference-style
//! "recommend k items" query served from the same kernel-tree index that
//! adaptive sampling trains against.
//!
//! The per-tree beam descent lives in
//! [`KernelTreeSampler::topk_beam`](crate::sampler::KernelTreeSampler::topk_beam)
//! (it shares the arena and the zero-mass guards with the draw path, and
//! runs on the ops layer: frontier masses are [`crate::ops::dot`] against
//! arena slices, surviving leaves are scored with one
//! `FeatureMap::kernel_many` sweep per contiguous class panel); this
//! module runs it across a shard set's pinned snapshots and merges the
//! per-shard candidates by exact kernel score. Merging is deterministic:
//! scores tie-break on global class id, and every shard is queried with the
//! same `k`/`beam_width`, so a result depends only on (snapshot
//! generations, h, k, beam_width).

use crate::sampler::kernel::FeatureMap;
use crate::serve::snapshot::TreeSnapshot;
use std::sync::Arc;

/// Retrieval tuning.
#[derive(Clone, Copy, Debug)]
pub struct TopKConfig {
    /// Results to return.
    pub k: usize,
    /// Beam width per shard tree; `≥` a shard's leaf count makes that
    /// shard's candidates exact.
    pub beam_width: usize,
}

impl Default for TopKConfig {
    fn default() -> Self {
        TopKConfig { k: 10, beam_width: 8 }
    }
}

/// One retrieval result: global class id, exact kernel score
/// `K(h, w) = ⟨φ(h), φ(w)⟩`, and the snapshot generation it came from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub class: u32,
    pub score: f64,
    pub generation: u64,
}

/// The one deterministic merge rule for per-shard top-k candidates: each
/// entry is `(shard offset, that shard's local (class, score) list)`; the
/// result is global ids ranked by descending score with class-id
/// tie-break, truncated to `k`. [`ShardedKernelSampler::topk_beam`] and
/// [`topk_over_snapshots`] both delegate here, so training-side and
/// serve-side retrieval can never disagree on the ordering contract.
///
/// [`ShardedKernelSampler::topk_beam`]: crate::serve::ShardedKernelSampler::topk_beam
pub fn merge_shard_topk(per_shard: Vec<(u32, Vec<(u32, f64)>)>, k: usize) -> Vec<(u32, f64)> {
    let mut merged: Vec<(u32, f64)> = per_shard
        .into_iter()
        .flat_map(|(offset, hits)| {
            hits.into_iter().map(move |(local, score)| (offset + local, score))
        })
        .collect();
    merged.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    merged.truncate(k);
    merged
}

/// Approximate top-k by kernel score across a shard set's snapshots.
/// `snaps[s]` serves global classes `offsets[s]..offsets[s+1]`.
pub fn topk_over_snapshots<M: FeatureMap>(
    snaps: &[Arc<TreeSnapshot<M>>],
    offsets: &[u32],
    h: &[f32],
    cfg: TopKConfig,
) -> Vec<Hit> {
    debug_assert_eq!(offsets.len(), snaps.len() + 1);
    let merged = merge_shard_topk(
        snaps
            .iter()
            .enumerate()
            .map(|(sid, snap)| {
                (offsets[sid], snap.tree.view().topk_beam(h, cfg.k, cfg.beam_width))
            })
            .collect(),
        cfg.k,
    );
    merged
        .into_iter()
        .map(|(class, score)| Hit {
            class,
            score,
            generation: snaps[crate::serve::shard::shard_of_class(offsets, class as usize)]
                .generation,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::KernelTreeSampler;
    use crate::serve::shard::shard_offsets;
    use crate::util::rng::Rng;
    // FeatureMap (for map.kernel in the oracle) comes in via `use super::*`.

    fn snapshot_shards(
        emb: &[f32],
        n: usize,
        d: usize,
        shards: usize,
    ) -> (Vec<Arc<TreeSnapshot<QuadraticMap>>>, Vec<u32>) {
        let offsets = shard_offsets(n, shards);
        let snaps = offsets
            .windows(2)
            .map(|w| {
                let (lo, hi) = (w[0] as usize, w[1] as usize);
                let mut t =
                    KernelTreeSampler::new(QuadraticMap::new(d, 100.0), hi - lo, Some(3));
                t.reset_embeddings(&emb[lo * d..hi * d], hi - lo, d);
                Arc::new(TreeSnapshot { generation: 7, tree: t })
            })
            .collect();
        (snaps, offsets)
    }

    #[test]
    fn merged_snapshot_topk_matches_exact_with_wide_beam() {
        let (n, d) = (40, 3);
        let mut rng = Rng::new(3);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let (snaps, offsets) = snapshot_shards(&emb, n, d, 4);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let map = QuadraticMap::new(d, 100.0);
        let mut exact: Vec<(u32, f64)> = (0..n as u32)
            .map(|c| (c, map.kernel(&h, &emb[c as usize * d..(c as usize + 1) * d])))
            .collect();
        exact.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let hits = topk_over_snapshots(&snaps, &offsets, &h, TopKConfig { k: 8, beam_width: n });
        assert_eq!(hits.len(), 8);
        for (i, (hit, (ec, es))) in hits.iter().zip(&exact).enumerate() {
            assert_eq!(hit.class, *ec, "rank {i}");
            assert!((hit.score - es).abs() < 1e-9 * es.max(1.0));
            assert_eq!(hit.generation, 7);
        }
    }

    #[test]
    fn narrow_beam_is_deterministic_and_well_formed() {
        let (n, d) = (64, 2);
        let mut rng = Rng::new(5);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.4);
        let (snaps, offsets) = snapshot_shards(&emb, n, d, 3);
        let h = vec![0.8f32, -0.6];
        let cfg = TopKConfig { k: 5, beam_width: 2 };
        let a = topk_over_snapshots(&snaps, &offsets, &h, cfg);
        let b = topk_over_snapshots(&snaps, &offsets, &h, cfg);
        assert_eq!(a, b, "same inputs must produce the same ranking");
        assert_eq!(a.len(), 5);
        let mut ids: Vec<u32> = a.iter().map(|hit| hit.class).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5, "duplicate classes in merged top-k");
        assert!(a.windows(2).all(|w| w[0].score >= w[1].score), "not sorted by score");
    }
}
