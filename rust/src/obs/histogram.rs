//! Log-scale-bucketed histogram with a lock-free record path.
//!
//! Bucketing is the IEEE-754 bit trick: for a positive finite f64, the
//! bit pattern `(v.to_bits() >> 49)` is *monotone in v* — it concatenates
//! the biased exponent with the top [`SUB_BITS`] mantissa bits — so a
//! bucket index is one shift and two compares, no log calls. Each octave
//! splits into `2^SUB_BITS = 8` *linearly spaced* sub-buckets (mantissa
//! bits, not geometric), so relative bucket width ranges from 12.5% at
//! the bottom of a binade down to 6.7% at the top; the midpoint
//! representative bounds the quantile readout error at half the width,
//! worst case 6.25% relative. The tracked
//! range is `[2^-30, 2^14)` seconds-or-items (≈ 1ns .. 16384); values
//! outside clamp into the underflow/overflow buckets, and exact min/max
//! cells keep the tails honest.
//!
//! * **record** — one relaxed `fetch_add` on the bucket + count cells and
//!   a CAS-add on the sum; no locks, no allocation. Safe to call from
//!   every serve worker / pipeline thread concurrently.
//! * **snapshot / merge** — integer bucket adds, so
//!   `merge(snap(a), snap(b))` equals a snapshot of interleaved records
//!   exactly (pinned by a property test).
//! * **quantile** — rank walk over the cumulative counts; the bucket
//!   representative is clamped into the observed `[min, max]`, which
//!   makes degenerate (constant-value) histograms read back exactly.
//!
//! `python/tools/obs_port_check.py` ports this file line-for-line
//! (`struct.pack('<d')` reproduces `to_bits`) and checks the same pinned
//! index vectors as the unit tests below.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: `2^SUB_BITS` buckets per octave.
pub const SUB_BITS: u32 = 3;
/// Smallest tracked power of two (values below land in the underflow
/// bucket 0; `2^-30 ≈ 0.93ns`).
pub const MIN_EXP: i32 = -30;
/// First untracked power of two (values `>= 2^14 = 16384` land in the
/// overflow bucket).
pub const MAX_EXP: i32 = 14;

const LO_RAW: u64 = ((1023 + MIN_EXP) as u64) << SUB_BITS;
const HI_RAW: u64 = ((1023 + MAX_EXP) as u64) << SUB_BITS;
/// Total bucket count: the tracked octaves plus underflow + overflow.
pub const BUCKETS: usize = (HI_RAW - LO_RAW) as usize + 2;

/// Bucket index of `v`. Non-positive and NaN values count in the
/// underflow bucket (0) — recorded values are durations/sizes, so those
/// only arise from upstream bugs and must not panic the recorder.
#[inline]
pub fn bucket_of(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let raw = v.to_bits() >> (52 - SUB_BITS);
    if raw < LO_RAW {
        0
    } else if raw >= HI_RAW {
        BUCKETS - 1
    } else {
        (raw - LO_RAW) as usize + 1
    }
}

/// Lower bound of bucket `i` for `i in [1, BUCKETS-1]` (the upper bound
/// of bucket `i` is `bucket_lower(i + 1)`; `bucket_lower(BUCKETS - 1)` is
/// the overflow threshold `2^MAX_EXP`).
#[inline]
pub fn bucket_lower(i: usize) -> f64 {
    debug_assert!(i >= 1 && i <= BUCKETS - 1);
    let raw = LO_RAW + (i as u64 - 1);
    f64::from_bits(raw << (52 - SUB_BITS))
}

/// Midpoint representative reported for a rank that lands in bucket `i`.
#[inline]
fn representative(i: usize) -> f64 {
    if i == 0 {
        bucket_lower(1)
    } else if i >= BUCKETS - 1 {
        bucket_lower(BUCKETS - 1)
    } else {
        0.5 * (bucket_lower(i) + bucket_lower(i + 1))
    }
}

/// CAS-add for an f64 stored in an `AtomicU64` (lock-free; the histogram
/// sum and gauge cells use it).
#[inline]
pub(crate) fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Concurrent log-bucketed histogram. All cells are `AtomicU64`; `record`
/// is wait-free apart from the sum CAS. Construction allocates the one
/// flat bucket array; recording never allocates.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bits of Σv (approximate under heavy contention reordering —
    /// fp adds commute only approximately — but exact for the
    /// single-writer phase-timer use).
    sum_bits: AtomicU64,
    /// Positive-f64 bit patterns order like the floats, so min/max are
    /// plain integer `fetch_min`/`fetch_max` (non-positive clamps to 0).
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Histogram(count={})", self.count())
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(u64::MAX),
            max_bits: AtomicU64::new(0),
        }
    }

    /// Record one observation (seconds, items, …). Lock-free hot path.
    #[inline]
    pub fn record(&self, v: f64) {
        let i = bucket_of(v);
        // `bucket_of` is range-clamped by construction; `.get()` keeps the
        // recorder panic-free even if that invariant ever regresses.
        if let Some(b) = self.buckets.get(i) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.sum_bits, v);
        let clamped = if v > 0.0 { v } else { 0.0 };
        self.min_bits.fetch_min(clamped.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(clamped.to_bits(), Ordering::Relaxed);
    }

    /// Record `n` identical observations in one shot — the blocked-flush
    /// path: hot loops accumulate per-value counts in thread-local plain
    /// fields (e.g. the draw scratch's per-depth counters) and drain them
    /// here once per batch, so the per-draw cost is a plain integer add,
    /// not an atomic.
    #[inline]
    pub fn record_n(&self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        let i = bucket_of(v);
        if let Some(b) = self.buckets.get(i) {
            b.fetch_add(n, Ordering::Relaxed);
        }
        self.count.fetch_add(n, Ordering::Relaxed);
        atomic_f64_add(&self.sum_bits, v * n as f64);
        let clamped = if v > 0.0 { v } else { 0.0 };
        self.min_bits.fetch_min(clamped.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(clamped.to_bits(), Ordering::Relaxed);
    }

    /// Number of recorded observations so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy for aggregation and readout.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            min_bits: self.min_bits.load(Ordering::Relaxed),
            max_bits: self.max_bits.load(Ordering::Relaxed),
        }
    }
}

/// Plain-integer snapshot of a [`Histogram`]; merging two snapshots is
/// elementwise addition, so shard aggregation is exact.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min_bits: u64,
    max_bits: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        HistogramSnapshot {
            buckets: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min_bits: u64::MAX,
            max_bits: 0,
        }
    }

    /// Fold `other` into `self`: bucket-wise integer adds, min/max of the
    /// extremes. `merge(snap_a, snap_b)` equals the snapshot of the
    /// interleaved record stream exactly (bucket counts and count; the fp
    /// sum is associative-order dependent only in the last ulps).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min_bits = self.min_bits.min(other.min_bits);
        self.max_bits = self.max_bits.max(other.max_bits);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Raw bucket occupancies (length [`BUCKETS`]); index 0 is the
    /// underflow bucket, the last is overflow.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest recorded value (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 || self.min_bits == u64::MAX {
            0.0
        } else {
            f64::from_bits(self.min_bits)
        }
    }

    /// Largest recorded value (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            f64::from_bits(self.max_bits)
        }
    }

    /// Quantile readout: the midpoint representative of the bucket holding
    /// the `ceil(q·count)`-th smallest observation, clamped into the exact
    /// observed `[min, max]`. Relative error vs an exact sort is bounded
    /// by half a bucket width (≈ 4.6%); a constant-valued histogram reads
    /// back its value exactly thanks to the clamp.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += *b;
            if cum >= rank {
                return representative(i).clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Pinned index vectors — the same table is asserted by
    /// `python/tools/obs_port_check.py`; a change to the bucketing
    /// constants must update both or CI fails.
    #[test]
    fn bucket_pins() {
        assert_eq!(BUCKETS, 354);
        for (v, want) in [
            (1e-9, 1usize),
            (1e-6, 81),
            (1e-3, 161),
            (0.5, 233),
            (1.0, 241),
            (1.5, 245),
            (3.0, 253),
            (1000.0, 320),
            (20000.0, 353),
            (0.0, 0),
            (-1.0, 0),
            (f64::NAN, 0),
        ] {
            assert_eq!(bucket_of(v), want, "bucket_of({v})");
        }
        assert_eq!(bucket_lower(BUCKETS - 1), 16384.0);
        assert!((bucket_lower(161) - 0.0009765625).abs() < 1e-18);
    }

    #[test]
    fn bucket_monotone_in_value() {
        let mut rng = Rng::new(7);
        let mut vals: Vec<f64> = (0..4000)
            .map(|_| {
                let e = rng.f64() * 50.0 - 32.0; // 2^-32 .. 2^18 incl. clamps
                2f64.powf(e)
            })
            .collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in vals.windows(2) {
            assert!(bucket_of(w[0]) <= bucket_of(w[1]), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn merge_equals_interleaved() {
        let mut rng = Rng::new(11);
        let a = Histogram::new();
        let b = Histogram::new();
        let both = Histogram::new();
        for i in 0..5000 {
            let v = rng.f64() * 1e3 + 1e-6;
            both.record(v);
            if i % 2 == 0 { &a } else { &b }.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let full = both.snapshot();
        assert_eq!(merged.buckets, full.buckets);
        assert_eq!(merged.count(), full.count());
        assert_eq!(merged.min_bits, full.min_bits);
        assert_eq!(merged.max_bits, full.max_bits);
        assert!((merged.sum() - full.sum()).abs() <= 1e-9 * full.sum().abs());
    }

    #[test]
    fn quantile_error_bounded_vs_exact_sort() {
        let mut rng = Rng::new(23);
        for trial in 0..20 {
            let h = Histogram::new();
            let n = 200 + (trial * 37) % 800;
            let mut vals: Vec<f64> = (0..n)
                .map(|_| 2f64.powf(rng.f64() * 24.0 - 18.0)) // 2^-18..2^6
                .collect();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let s = h.snapshot();
            for &q in &[0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let rank = ((q * n as f64).ceil() as usize).max(1);
                let exact = vals[rank - 1];
                let got = s.quantile(q);
                let rel = (got - exact).abs() / exact;
                // worst-case midpoint error is 6.25% (half the 12.5%-wide
                // bottom sub-bucket of a binade); the 2^-18..2^6 stream
                // does hit it, so the bound is the real invariant
                assert!(rel <= 0.0625, "trial {trial} q {q}: {got} vs exact {exact} (rel {rel})");
            }
        }
    }

    #[test]
    fn constant_value_reads_back_exact() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(0.125);
        }
        let s = h.snapshot();
        assert_eq!(s.p50(), 0.125);
        assert_eq!(s.p99(), 0.125);
        assert_eq!(s.min(), 0.125);
        assert_eq!(s.max(), 0.125);
        assert!((s.mean() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn concurrent_recorders_consistent() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    let mut local_sum = 0.0;
                    for _ in 0..10_000 {
                        let v = rng.f64() + 1e-3;
                        h.record(v);
                        local_sum += v;
                    }
                    local_sum
                })
            })
            .collect();
        let expect_sum: f64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
        let s = h.snapshot();
        assert_eq!(s.count(), 40_000);
        assert_eq!(s.buckets.iter().sum::<u64>(), 40_000);
        // CAS-add loses no updates; only summation order differs.
        assert!((s.sum() - expect_sum).abs() <= 1e-6 * expect_sum);
        assert!(s.min() >= 1e-3 && s.max() < 1.0 + 1e-3 + 1e-12);
    }

    #[test]
    fn empty_snapshot_is_benign() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
    }
}
