// pallas-lint REG fixture (consistent): registry, arms, help and README
// all agree.

pub struct SamplerInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

pub const SAMPLER_REGISTRY: &[SamplerInfo] = &[
    SamplerInfo { name: "uniform", summary: "uniform over classes" },
    SamplerInfo { name: "softmax", summary: "exact softmax oracle" },
];

pub fn build_sampler(name: &str) -> Result<u32, String> {
    match name {
        "uniform" => Ok(0),
        "softmax" => Ok(1),
        other => Err(format!("unknown sampler '{other}'")),
    }
}
