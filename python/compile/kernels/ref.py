"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a straight-line jnp twin here; the
pytest suite asserts elementwise agreement (values and gradients) across
shape/dtype/seed sweeps. These functions also serve as the executable
specification of the paper's equations:

* eq. (2): adjusted logits ``o'_i = o_{s_i} - ln(m q_{s_i})`` for negatives
  (the positive is uncorrected),
* eq. (3): sampled softmax ``p'`` over the adjusted logits and the sampled
  cross-entropy loss,
* eq. (5): the gradient of the sampled loss w.r.t. the logits is ``p' - y'``,
* eq. (11): the *absolute softmax* variant ``p_i ∝ exp(|o_i|)`` used when
  sampling from symmetric kernels such as the quadratic kernel (§3.3).
"""

import jax.numpy as jnp


def _logsumexp(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    return (m + jnp.log(jnp.sum(jnp.exp(x - m), axis=axis, keepdims=True))).squeeze(axis)


def adjusted_logits(logits, sub, abs_logits=False):
    """Apply §3.3's optional |o| and eq. (2)'s sampling correction.

    Args:
      logits: (N, S) raw logits of the sampled classes; column 0 is the
        positive class.
      sub: (N, S) corrections; by construction ``sub[:, 0] == 0`` (the
        positive class is not corrected) and ``sub[:, j] = ln(m q_j)`` for
        the sampled negatives.
      abs_logits: use the absolute-softmax prediction distribution.

    Returns: (N, S) adjusted logits ``o'``.
    """
    if abs_logits:
        logits = jnp.abs(logits)
    return logits - sub


def sampled_softmax_loss_ref(h, ws, sub, abs_logits=False):
    """Cross-entropy of sampled softmax (eqs. 2-3), positive at column 0.

    Args:
      h: (N, d) query embeddings (the model's last hidden layer).
      ws: (N, S, d) class embeddings of the sample; ``S = m + 1``.
      sub: (N, S) ``ln(m q)`` corrections (0 for the positive column).

    Returns: (N,) per-example loss ``-log p'_0``.
    """
    logits = jnp.einsum("nsd,nd->ns", ws, h)
    adj = adjusted_logits(logits, sub, abs_logits)
    return _logsumexp(adj) - adj[:, 0]


def sampled_softmax_grad_logits_ref(h, ws, sub, abs_logits=False):
    """Gradient of the per-example loss w.r.t. the *raw* logits (eq. 5).

    Returns: (N, S) ``(p' - y') * d|o|/do`` where the last factor is
    ``sign(o)`` under absolute softmax and 1 otherwise.
    """
    logits = jnp.einsum("nsd,nd->ns", ws, h)
    adj = adjusted_logits(logits, sub, abs_logits)
    p = jnp.exp(adj - _logsumexp(adj)[:, None])
    y = jnp.zeros_like(p).at[:, 0].set(1.0)
    g = p - y
    if abs_logits:
        g = g * jnp.sign(logits)
    return g


def full_softmax_loss_ref(h, w, pos, abs_logits=False):
    """Full softmax cross entropy over all n classes (eq. 1 / eq. 11).

    Args:
      h: (N, d) query embeddings.
      w: (n, d) output class embedding table.
      pos: (N,) int32 index of the positive class per example.

    Returns: (N,) per-example loss.
    """
    logits = h @ w.T
    if abs_logits:
        logits = jnp.abs(logits)
    lse = _logsumexp(logits)
    pos_logit = jnp.take_along_axis(logits, pos[:, None].astype(jnp.int32), axis=1)[:, 0]
    return lse - pos_logit


def softmax_probs_ref(h, w, abs_logits=False):
    """The prediction distribution p (eq. 1 / eq. 11); also the only unbiased
    sampling distribution (Theorem 2.1)."""
    logits = h @ w.T
    if abs_logits:
        logits = jnp.abs(logits)
    return jnp.exp(logits - _logsumexp(logits)[:, None])


def quadratic_kernel_ref(h, w, alpha=100.0):
    """The paper's quadratic kernel: ``K(h, w_i) = α⟨h, w_i⟩² + 1`` (§3.3)."""
    return alpha * (h @ w.T) ** 2 + 1.0


def quartic_kernel_ref(h, w):
    """The PTB extra from Figure 2: ``q_i ∝ ⟨h, w_i⟩⁴ + 1``."""
    return (h @ w.T) ** 4 + 1.0


def phi_quadratic_ref(a, alpha=100.0):
    """Feature map of the quadratic kernel, eq. (10):
    ``φ(a) = [√α vec(a ⊗ a), 1]`` with ``D = d² + 1``.

    The rust tree stores ``z(C) = Σ φ(w_j)`` built from this map; this oracle
    pins down the exact layout (row-major outer product, constant last) that
    `rust/src/sampler/kernel/mod.rs` mirrors."""
    outer = jnp.einsum("i,j->ij", a, a).reshape(-1)
    return jnp.concatenate([jnp.sqrt(jnp.asarray(alpha, a.dtype)) * outer, jnp.ones((1,), a.dtype)])


def phi_rff_ref(a, omega):
    """Positive random feature map of the exponential kernel (Rawat et al.,
    2019): ``φ(a)_i = exp(ω_iᵀa − ‖a‖²/2) / √D`` for ``ω`` of shape (D, d),
    so ``E_ω[⟨φ(a), φ(b)⟩] = exp(aᵀb)`` and every component is positive.

    Pins the layout the rust ``PositiveRffMap`` mirrors
    (`rust/src/sampler/rff/map.rs`): component ``i`` is frequency *row* ``i``
    of the row-major (D × d) ``ω``, prefactor folded into each component."""
    proj = omega @ a
    return jnp.exp(proj - 0.5 * jnp.dot(a, a)) / jnp.sqrt(jnp.asarray(omega.shape[0], a.dtype))


def rff_kernel_ref(a, b, omega):
    """The realized random kernel ``K̂(a,b) = ⟨φ(a), φ(b)⟩`` in its factored
    closed form — the quantity the rust tree's leaf scoring computes."""
    return jnp.exp(omega @ (a + b) - 0.5 * (jnp.dot(a, a) + jnp.dot(b, b))).sum() / omega.shape[0]
