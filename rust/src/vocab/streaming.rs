//! The two-tier streaming sampler: tier router + owning trainer sampler.
//!
//! # Tier router algebra
//!
//! [`draw_from_tiers`] is the shard-router algebra of
//! [`crate::serve::shard`] specialized to two heterogeneous tiers:
//!
//! ```text
//!   M_arena = ⟨φ(h), z(root)⟩ − Σ_tombstoned K(h, w_t)   (mass exclusion)
//!   M_mem   = Σ_memtable K(h, w_j)
//!   P(tier) = M_tier / (M_arena + M_mem)
//!   q(c)    = P(tier) · q_tier(c) = K(h, w_c) / ΣM
//! ```
//!
//! which is exactly the distribution of a single kernel tree over the
//! live union — the per-class numerator is the same kernel score, and the
//! denominator differs only in floating-point association of the same
//! positive terms (≤ 1e-12 relative at practical sizes; the compaction
//! policy bounds the cancellation of the mass-exclusion subtraction, see
//! [`crate::vocab::CompactionPolicy`]). On the clean path the code
//! reports the cancelled form `K/ΣM` directly.
//!
//! Tombstoned slots are handled by **rejection**: a tombstoned class's
//! kernel mass is already excluded from `M_arena`, so redrawing until a
//! live slot lands samples exactly the conditional distribution over live
//! arena classes. The redraw budget is bounded; exhausting it falls back
//! to a uniform live-slot scan (counted — it signals a violated
//! compaction policy) so the draw path stays panic-free with q > 0.
//!
//! Degenerate masses (all tiers sanitized to zero) fall back to a uniform
//! choice among populated tiers, reporting the product of probabilities
//! actually used — the same stance as the shard router and the in-tree
//! zero-mass guards.

use crate::ops;
use crate::sampler::kernel::tree::{sanitize_mass, step_down_to_positive, KernelTreeSampler};
use crate::sampler::kernel::FeatureMap;
use crate::sampler::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::Rng;
use crate::util::threadpool::Pool;
use crate::vocab::memtable::{Memtable, TombstoneSet};
use crate::vocab::{CompactionPolicy, VocabObs};
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

pub(crate) const TIER_ARENA: usize = 0;
pub(crate) const TIER_MEM: usize = 1;

/// Bounded redraw attempts in the arena tier before the uniform live-slot
/// fallback. The compaction policy caps tombstone mass at ~1/4 of the
/// arena, so the expected attempt count is ≤ 4/3 and the budget is
/// exhausted with probability ≤ (1/4)^64.
const REJECT_CAP: usize = 64;

/// Vocab-level draw scratch (the arena tree pools its own
/// [`crate::sampler::kernel::tree::DrawScratch`] internally — those
/// buffers are shape-bound to one tree and must not outlive a
/// compaction).
#[derive(Default)]
pub(crate) struct TierScratch {
    phi_h: Vec<f64>,
    tomb_k: Vec<f64>,
    tomb_cum: Vec<f64>,
    mem_w: Vec<f64>,
    mem_cum: Vec<f64>,
    masses: [f64; 2],
    cum: [f64; 2],
}

/// Draw `m` negatives from the two-tier composite into `out` (global
/// ids). See the module docs for the q algebra; panics are structurally
/// unreachable (every division is guarded, every fallback reports the
/// probability it actually used).
#[allow(clippy::too_many_arguments)]
pub(crate) fn draw_from_tiers<M: FeatureMap>(
    tree: &KernelTreeSampler<M>,
    arena_ids: &[u32],
    memtable: &Memtable,
    tombs: &TombstoneSet,
    h: &[f32],
    m: usize,
    s: &mut TierScratch,
    rng: &mut Rng,
    obs: &VocabObs,
    out: &mut Sample,
) -> Result<()> {
    let map = tree.feature_map();
    let arena_n = arena_ids.len();
    let arena_live_n = arena_n - tombs.len();
    let live_n = arena_live_n + memtable.len();
    anyhow::ensure!(live_n > 0, "streaming sampler has no live classes");

    // per-example tier masses (the router CDF)
    s.phi_h.resize(map.dim(), 0.0);
    map.phi(h, &mut s.phi_h);
    let arena_raw = tree.partition(&s.phi_h);
    let tomb_mass = tombs.mass(map, h, &mut s.tomb_k, &mut s.tomb_cum);
    memtable.weights_into(map, h, &mut s.mem_w);
    s.mem_cum.resize(memtable.len(), 0.0);
    let mem_mass = ops::fill_cum_into(&s.mem_w, &mut s.mem_cum);
    // a fully tombstoned arena must not keep fp residue of the
    // subtraction as drawable mass — there is no live slot to land on
    s.masses[TIER_ARENA] =
        if arena_live_n == 0 { 0.0 } else { sanitize_mass(arena_raw - tomb_mass) };
    s.masses[TIER_MEM] = if memtable.is_empty() { 0.0 } else { sanitize_mass(mem_mass) };
    let total = ops::fill_cum_into(&s.masses, &mut s.cum);

    // the arena descent scratch is pooled by the tree itself and primed
    // lazily — m memtable-tier draws never pay the arena setup
    let mut tree_scratch = None;

    for _ in 0..m {
        // tier choice — the shard-router CDF over 2 tiers
        let (tier, p_tier, clean) = if total > 0.0 && total.is_finite() {
            let u = rng.f64() * total;
            let idx = s.cum.partition_point(|&c| c <= u).min(1);
            let idx = step_down_to_positive(&s.cum, idx);
            (idx, s.masses[idx] / total, true)
        } else if arena_live_n > 0 && !memtable.is_empty() {
            // every tier's mass degenerated: uniform over populated tiers
            (rng.below(2) as usize, 0.5, false)
        } else if arena_live_n > 0 {
            (TIER_ARENA, 1.0, false)
        } else {
            (TIER_MEM, 1.0, false)
        };

        if tier == TIER_MEM {
            let (id, q) = if mem_mass > 0.0 && mem_mass.is_finite() {
                let (slot, id) = memtable.draw_prepared(&s.mem_cum, mem_mass, rng);
                let q = if clean {
                    // (M_mem/ΣM)·(k/M_mem) = k/ΣM — the union-tree form
                    (s.mem_w[slot] / total).clamp(f64::MIN_POSITIVE, f64::MAX)
                } else {
                    let lo = if slot == 0 { 0.0 } else { s.mem_cum[slot - 1] };
                    (p_tier * ((s.mem_cum[slot] - lo) / mem_mass))
                        .clamp(f64::MIN_POSITIVE, f64::MAX)
                };
                (id, q)
            } else {
                // degenerate memtable mass: uniform over its slots
                let slot = rng.below(memtable.len() as u64) as usize;
                let q = (p_tier / memtable.len() as f64).clamp(f64::MIN_POSITIVE, f64::MAX);
                (memtable.id_at(slot), q)
            };
            out.push(id, q);
            obs.tier_memtable.inc();
            continue;
        }

        // arena tier: tombstone mass is excluded from the router mass, so
        // rejecting tombstoned landings samples the live conditional
        let ts = tree_scratch.get_or_insert_with(|| {
            let mut sc = tree.take_scratch();
            tree.begin_example_prepared(&s.phi_h, arena_raw, &mut sc);
            sc
        });
        let mut chosen = None;
        for _ in 0..REJECT_CAP {
            let (slot, q_tree) = tree.draw(h, ts, rng);
            if !tombs.contains(slot) {
                chosen = Some((slot, q_tree));
                break;
            }
            obs.tombstone_rejects.inc();
        }
        let (slot, q) = match chosen {
            Some((slot, q_tree)) => {
                let q = if clean {
                    // (M_arena/ΣM)·(k/M_arena) = k/ΣM — the union-tree form
                    let k = sanitize_mass(map.kernel(h, tree.emb_row(slot as usize)));
                    (k / total).clamp(f64::MIN_POSITIVE, f64::MAX)
                } else {
                    (p_tier * q_tree).clamp(f64::MIN_POSITIVE, f64::MAX)
                };
                (slot, q)
            }
            None => {
                // budget exhausted (tombstone mass ≫ live mass — a
                // violated compaction policy): uniform over live slots,
                // counted so operators can see the policy failure
                obs.reject_overflows.inc();
                let pick = rng.below(arena_live_n as u64) as usize;
                let mut slot = 0u32;
                let mut seen = 0usize;
                for cand in 0..arena_n as u32 {
                    if tombs.contains(cand) {
                        continue;
                    }
                    if seen == pick {
                        slot = cand;
                        break;
                    }
                    seen += 1;
                }
                let q = (p_tier / arena_live_n as f64).clamp(f64::MIN_POSITIVE, f64::MAX);
                (slot, q)
            }
        };
        out.push(arena_ids[slot as usize], q);
        obs.tier_arena.inc();
    }
    if let Some(ts) = tree_scratch {
        tree.put_scratch(ts);
    }
    Ok(())
}

/// Composite probability of one live class (`None` for tombstoned or
/// unknown ids, and on fully degenerate mass — the same stance as the
/// shard sampler's `prob`).
pub(crate) fn prob_from_tiers<M: FeatureMap>(
    tree: &KernelTreeSampler<M>,
    arena_index: &HashMap<u32, u32>,
    memtable: &Memtable,
    tombs: &TombstoneSet,
    h: &[f32],
    class: u32,
) -> Option<f64> {
    let map = tree.feature_map();
    let k = if let Some(slot) = memtable.slot_of(class) {
        map.kernel(h, memtable.row(slot))
    } else {
        let &slot = arena_index.get(&class)?;
        if tombs.contains(slot) {
            return None;
        }
        map.kernel(h, tree.emb_row(slot as usize))
    };
    let phi_h = tree.phi_query(h);
    let arena_raw = tree.partition(&phi_h);
    let tomb_mass = tombs.mass(map, h, &mut Vec::new(), &mut Vec::new());
    let mut mem_w = Vec::new();
    memtable.weights_into(map, h, &mut mem_w);
    let mut mem_cum = vec![0.0; mem_w.len()];
    let mem_mass = ops::fill_cum_into(&mem_w, &mut mem_cum);
    let arena_live_n = arena_index.len() - tombs.len();
    let m_arena = if arena_live_n == 0 { 0.0 } else { sanitize_mass(arena_raw - tomb_mass) };
    let m_mem = if memtable.is_empty() { 0.0 } else { sanitize_mass(mem_mass) };
    let total = m_arena + m_mem;
    if !(total > 0.0 && total.is_finite()) {
        return None;
    }
    Some(k / total)
}

/// The owning streaming sampler (registry names `quadratic-streaming`,
/// `rff-streaming`): a kernel-tree arena over **slots** with an explicit
/// slot → global-id map, a memtable for inserts, a tombstone set for
/// retirements, and a self-driving compactor. Draws report *global* class
/// ids — after churn the id space has holes, which is the point.
pub struct StreamingKernelSampler<M: FeatureMap + Clone> {
    name: String,
    tree: KernelTreeSampler<M>,
    /// arena slot → global class id.
    arena_ids: Vec<u32>,
    /// global class id → arena slot (tombstoned slots stay mapped; draws
    /// mask them, compaction evicts them).
    arena_index: HashMap<u32, u32>,
    memtable: Memtable,
    tombs: TombstoneSet,
    next_id: u32,
    policy: CompactionPolicy,
    leaf_size: Option<usize>,
    ops_since_compact: u64,
    scratch: Pool<TierScratch>,
    obs: VocabObs,
}

impl<M: FeatureMap + Clone> StreamingKernelSampler<M> {
    /// Start with a dense arena over global ids `0..n_classes` (all-zero
    /// embeddings until [`Sampler::reset_embeddings`]).
    pub fn new(map: M, n_classes: usize, leaf_size: Option<usize>) -> Self {
        let d = map.d();
        let name = format!("{}-streaming", map.name());
        let tree = KernelTreeSampler::new(map, n_classes, leaf_size);
        StreamingKernelSampler {
            name,
            tree,
            arena_ids: (0..n_classes as u32).collect(),
            arena_index: (0..n_classes as u32).map(|i| (i, i)).collect(),
            memtable: Memtable::new(d),
            tombs: TombstoneSet::new(d),
            next_id: n_classes as u32,
            policy: CompactionPolicy::default(),
            leaf_size,
            ops_since_compact: 0,
            scratch: Pool::new(),
            obs: VocabObs::default(),
        }
    }

    pub fn with_policy(mut self, policy: CompactionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Telemetry cells (register via [`VocabObs::register_into`]).
    pub fn obs(&self) -> &VocabObs {
        &self.obs
    }

    fn d(&self) -> usize {
        self.memtable.d()
    }

    /// Live classes: arena minus tombstones plus memtable.
    pub fn live_len(&self) -> usize {
        self.arena_ids.len() - self.tombs.len() + self.memtable.len()
    }

    pub fn memtable_len(&self) -> usize {
        self.memtable.len()
    }

    pub fn tombstone_len(&self) -> usize {
        self.tombs.len()
    }

    pub fn is_live(&self, id: u32) -> bool {
        self.memtable.contains(id)
            || self.arena_index.get(&id).is_some_and(|&slot| !self.tombs.contains(slot))
    }

    /// Insert a new class with a fresh id; returns the id.
    pub fn insert_class(&mut self, row: &[f32]) -> u32 {
        let id = self.next_id;
        self.insert_class_with_id(id, row).expect("fresh id cannot be live");
        id
    }

    /// Insert under a caller-chosen id (errors if that id is live — a
    /// *tombstoned* id may be re-inserted; the arena copy stays masked
    /// until compaction evicts it).
    pub fn insert_class_with_id(&mut self, id: u32, row: &[f32]) -> Result<()> {
        anyhow::ensure!(!self.is_live(id), "class {id} is already live");
        self.memtable.insert(id, row)?;
        self.next_id = self.next_id.max(id.saturating_add(1));
        self.obs.inserts.inc();
        self.obs.memtable_size.set(self.memtable.len() as f64);
        self.ops_since_compact += 1;
        self.maybe_compact();
        Ok(())
    }

    /// Retire a live class. Memtable residents simply leave the memtable;
    /// arena classes are tombstoned (mass excluded, draws rejected) until
    /// the next compaction. Returns false for non-live ids, and refuses to
    /// retire the last live class (an empty vocabulary cannot sample).
    pub fn retire_class(&mut self, id: u32) -> bool {
        if self.live_len() <= 1 {
            return false;
        }
        if self.memtable.remove(id) {
            self.obs.retires.inc();
            self.obs.memtable_size.set(self.memtable.len() as f64);
            self.ops_since_compact += 1;
            return true;
        }
        let Some(&slot) = self.arena_index.get(&id) else {
            return false;
        };
        if self.tombs.contains(slot) {
            return false;
        }
        let row = self.tree.emb_row(slot as usize).to_vec();
        self.tombs.insert(slot, &row);
        self.obs.retires.inc();
        self.obs.tombstones.set(self.tombs.len() as f64);
        self.ops_since_compact += 1;
        self.maybe_compact();
        true
    }

    /// The live class set in canonical compaction order: arena slots
    /// ascending (tombstones skipped), then memtable slots. This is
    /// exactly the layout [`StreamingKernelSampler::compact`] rebuilds
    /// the arena from — the bitwise-equal-to-rebuild property tests pin
    /// that.
    pub fn live_classes(&self) -> (Vec<u32>, Vec<f32>) {
        let d = self.d();
        let n = self.arena_ids.len();
        let live = self.live_len();
        let mut ids = Vec::with_capacity(live);
        let mut rows = Vec::with_capacity(live * d);
        for slot in 0..n {
            if self.tombs.contains(slot as u32) {
                continue;
            }
            ids.push(self.arena_ids[slot]);
            rows.extend_from_slice(self.tree.emb_row(slot));
        }
        ids.extend_from_slice(self.memtable.ids());
        rows.extend_from_slice(self.memtable.rows());
        (ids, rows)
    }

    /// Fold the memtable into the arena and drop tombstones: gather the
    /// live rows in canonical order and build a fresh dense tree — by
    /// construction bitwise-equal to a from-scratch rebuild over the live
    /// set. O(C) work, paid once per policy trigger instead of per op.
    pub fn compact(&mut self) {
        let t = Instant::now();
        let (ids, rows) = self.live_classes();
        let d = self.d();
        let n = ids.len();
        let map = self.tree.feature_map().clone();
        let mut tree = KernelTreeSampler::new(map, n, self.leaf_size);
        tree.reset_embeddings(&rows, n, d);
        self.tree = tree;
        self.arena_index =
            ids.iter().enumerate().map(|(slot, &gid)| (gid, slot as u32)).collect();
        self.arena_ids = ids;
        self.memtable.clear();
        self.tombs.clear();
        self.obs.compaction_seconds.record(t.elapsed().as_secs_f64());
        self.obs.compaction_lag_ops.record(self.ops_since_compact as f64);
        self.ops_since_compact = 0;
        self.obs.memtable_size.set(0.0);
        self.obs.tombstones.set(0.0);
    }

    fn maybe_compact(&mut self) {
        if self.policy.should_compact(
            self.arena_ids.len(),
            self.tombs.len(),
            self.memtable.len(),
        ) {
            self.compact();
        }
    }

    /// Churn-aware batched update: memtable rows are patched in place
    /// (their mass refreshes on the next draw), tombstoned and unknown
    /// ids are dropped (counted — the frozen tombstone rows must keep
    /// matching the arena), and the rest becomes one aggregated
    /// kernel-tree sweep over arena slots.
    fn update_many_routed(&mut self, classes: &[usize], rows: &[f32]) {
        if classes.is_empty() {
            return;
        }
        let d = rows.len() / classes.len();
        debug_assert_eq!(d, self.d());
        let mut arena: Vec<(u32, usize)> = Vec::new();
        for (i, &gid) in classes.iter().enumerate() {
            let gid = gid as u32;
            let row = &rows[i * d..(i + 1) * d];
            if self.memtable.update_row(gid, row) {
                continue;
            }
            match self.arena_index.get(&gid) {
                Some(&slot) if !self.tombs.contains(slot) => arena.push((slot, i)),
                _ => self.obs.dropped_updates.inc(),
            }
        }
        if !arena.is_empty() {
            // global ids arrive sorted, but slot order is a permutation of
            // id order after compaction — re-sort for the tree contract
            arena.sort_unstable_by_key(|&(slot, _)| slot);
            let mut slots = Vec::with_capacity(arena.len());
            let mut flat = Vec::with_capacity(arena.len() * d);
            for &(slot, i) in &arena {
                slots.push(slot as usize);
                flat.extend_from_slice(&rows[i * d..(i + 1) * d]);
            }
            self.tree.update_many(&slots, &flat);
        }
        self.ops_since_compact += 1;
    }
}

impl<M: FeatureMap + Clone> Sampler for StreamingKernelSampler<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn needs(&self) -> Needs {
        Needs { h: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        out.clear();
        let h = input
            .h
            .ok_or_else(|| anyhow::anyhow!("sampler '{}' needs the query embedding h", self.name))?;
        let mut s = self.scratch.take(TierScratch::default);
        let res = draw_from_tiers(
            &self.tree,
            &self.arena_ids,
            &self.memtable,
            &self.tombs,
            h,
            m,
            &mut s,
            rng,
            &self.obs,
            out,
        );
        self.scratch.put(s);
        res
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let h = input.h?;
        prob_from_tiers(&self.tree, &self.arena_index, &self.memtable, &self.tombs, h, class)
    }

    fn update(&mut self, class: usize, w_new: &[f32]) {
        self.update_many_routed(&[class], w_new);
    }

    fn update_many(&mut self, classes: &[usize], rows: &[f32]) {
        self.update_many_routed(classes, rows);
    }

    /// Reset to a dense live set over global ids `0..n` (fresh stream:
    /// memtable and tombstones are dropped, the id counter restarts at
    /// `n`).
    fn reset_embeddings(&mut self, w: &[f32], n: usize, d: usize) {
        debug_assert_eq!(d, self.d());
        let map = self.tree.feature_map().clone();
        let mut tree = KernelTreeSampler::new(map, n, self.leaf_size);
        tree.reset_embeddings(w, n, d);
        self.tree = tree;
        self.arena_ids = (0..n as u32).collect();
        self.arena_index = (0..n as u32).map(|i| (i, i)).collect();
        self.memtable.clear();
        self.tombs.clear();
        self.next_id = n as u32;
        self.ops_since_compact = 0;
        self.obs.memtable_size.set(0.0);
        self.obs.tombstones.set(0.0);
    }

    fn owns_kernel_tree(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::rff::{PositiveRffMap, RffConfig, RFF_BUILD_SEED};
    use crate::util::testing::check;

    const ALPHA: f64 = 100.0;

    /// Test-side mirror of the live class set: (global id, row) pairs in
    /// insertion order, with a from-scratch single-tree builder — the
    /// ISSUE's reference distribution.
    struct Mirror {
        d: usize,
        live: Vec<(u32, Vec<f32>)>,
    }

    impl Mirror {
        fn slot_of(&self, gid: u32) -> Option<usize> {
            self.live.iter().position(|&(g, _)| g == gid)
        }

        fn build(&self) -> KernelTreeSampler<QuadraticMap> {
            let n = self.live.len();
            let mut rows = Vec::with_capacity(n * self.d);
            for (_, r) in &self.live {
                rows.extend_from_slice(r);
            }
            let mut t = KernelTreeSampler::new(QuadraticMap::new(self.d, ALPHA), n, Some(4));
            t.reset_embeddings(&rows, n, self.d);
            t
        }
    }

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(a.abs())
    }

    #[test]
    fn streaming_q_matches_from_scratch_tree_through_interleaved_schedule() {
        // the ISSUE acceptance property: at EVERY point of an interleaved
        // insert/retire/update/compact schedule, the composite q of each
        // draw matches a from-scratch single tree over the live class set
        // to ≤ 1e-12 relative, and tombstoned classes are never drawn
        check("vocab.streaming_matches_single_tree", 8, |g| {
            let n0 = g.usize_in(8, 20);
            let d = g.usize_in(2, 4);
            let seed = g.case_seed;
            let mut rng = Rng::new(seed);
            let mut emb = vec![0.0f32; n0 * d];
            rng.fill_normal(&mut emb, 0.6);

            let mut s = StreamingKernelSampler::new(QuadraticMap::new(d, ALPHA), n0, Some(4))
                .with_policy(CompactionPolicy::manual());
            s.reset_embeddings(&emb, n0, d);
            let mut mirror = Mirror {
                d,
                live: (0..n0)
                    .map(|i| (i as u32, emb[i * d..(i + 1) * d].to_vec()))
                    .collect(),
            };

            let mut retired: Vec<u32> = Vec::new();
            for step in 0..40 {
                // one mutation per step, interleaved kinds
                match step % 8 {
                    0 | 3 | 6 => {
                        let mut row = vec![0.0f32; d];
                        rng.fill_normal(&mut row, 0.6);
                        let id = s.insert_class(&row);
                        mirror.live.push((id, row));
                    }
                    1 | 5 => {
                        if mirror.live.len() > 3 {
                            let pick = rng.below(mirror.live.len() as u64) as usize;
                            let gid = mirror.live[pick].0;
                            assert!(s.retire_class(gid), "retire live id {gid}");
                            mirror.live.remove(pick);
                            retired.push(gid);
                        }
                    }
                    7 => {
                        s.compact();
                        assert_eq!(s.memtable_len(), 0);
                        assert_eq!(s.tombstone_len(), 0);
                    }
                    _ => {
                        // batched update of a few live classes (sorted ids)
                        let k = 1 + rng.below(3) as usize;
                        let mut picks: Vec<usize> = (0..mirror.live.len()).collect();
                        rng.shuffle(&mut picks);
                        picks.truncate(k.min(mirror.live.len()));
                        let mut gids: Vec<u32> =
                            picks.iter().map(|&p| mirror.live[p].0).collect();
                        gids.sort_unstable();
                        let mut flat = vec![0.0f32; gids.len() * d];
                        rng.fill_normal(&mut flat, 0.6);
                        for (i, &gid) in gids.iter().enumerate() {
                            let slot = mirror.slot_of(gid).unwrap();
                            mirror.live[slot].1.copy_from_slice(&flat[i * d..(i + 1) * d]);
                        }
                        let classes: Vec<usize> = gids.iter().map(|&g| g as usize).collect();
                        s.update_many(&classes, &flat);
                    }
                }
                assert_eq!(s.live_len(), mirror.live.len(), "step {step}");

                // the reference: a from-scratch single tree over the live set
                let reference = mirror.build();
                let mut h = vec![0.0f32; d];
                rng.fill_normal(&mut h, 1.0);
                let input = SampleInput { h: Some(&h), ..Default::default() };
                let mut out = Sample::default();
                let mut draw_rng = Rng::new(seed ^ (step as u64) << 32);
                s.sample(&input, 8, &mut draw_rng, &mut out).unwrap();
                for (&gid, &q) in out.classes.iter().zip(&out.q) {
                    assert!(
                        !retired.contains(&gid) || s.is_live(gid),
                        "step {step}: drew retired class {gid}"
                    );
                    let slot = mirror
                        .slot_of(gid)
                        .unwrap_or_else(|| panic!("step {step}: drew non-live class {gid}"));
                    let want = reference.prob(&input, slot as u32).unwrap();
                    assert!(
                        rel_close(q, want, 1e-12),
                        "step {step} class {gid}: q {q} vs single-tree {want}"
                    );
                }
                // prob agrees with the reference on every live class
                for (slot, &(gid, _)) in mirror.live.iter().enumerate() {
                    let got = s.prob(&input, gid).unwrap();
                    let want = reference.prob(&input, slot as u32).unwrap();
                    assert!(
                        rel_close(got, want, 1e-12),
                        "step {step} class {gid}: prob {got} vs {want}"
                    );
                }
                // and declines tombstoned ids
                for &gid in retired.iter().take(3) {
                    if !s.is_live(gid) {
                        assert_eq!(s.prob(&input, gid), None, "step {step}");
                    }
                }
            }
        });
    }

    #[test]
    fn tombstoned_classes_are_never_drawn_under_heavy_retirement() {
        let (n, d) = (32usize, 3usize);
        let mut rng = Rng::new(44);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.7);
        let mut s = StreamingKernelSampler::new(QuadraticMap::new(d, ALPHA), n, Some(4))
            .with_policy(CompactionPolicy::manual());
        s.reset_embeddings(&emb, n, d);
        // retire just under half the arena, no compaction
        let mut dead = Vec::new();
        for id in (0..n as u32).step_by(2).take(15) {
            assert!(s.retire_class(id));
            dead.push(id);
        }
        assert_eq!(s.tombstone_len(), 15);
        let h = vec![0.3f32, -0.8, 0.5];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        for round in 0..200 {
            s.sample(&input, 25, &mut Rng::new(round), &mut out).unwrap();
            for (&c, &q) in out.classes.iter().zip(&out.q) {
                assert!(!dead.contains(&c), "drew tombstoned class {c}");
                assert!(q > 0.0 && q.is_finite());
            }
        }
        assert!(s.obs().tier_arena_total() > 0);
        // tombstoned ids report no probability and drop updates countably
        assert_eq!(s.prob(&input, 0), None);
        let dropped_before = s.obs().dropped_update_total();
        s.update_many(&[0, 1], &vec![0.1f32; 2 * d]);
        assert_eq!(s.obs().dropped_update_total(), dropped_before + 1);
    }

    #[test]
    fn compaction_is_bitwise_equal_to_a_from_scratch_rebuild() {
        let (n, d) = (24usize, 3usize);
        let mut rng = Rng::new(55);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let mut s = StreamingKernelSampler::new(QuadraticMap::new(d, ALPHA), n, Some(4))
            .with_policy(CompactionPolicy::manual());
        s.reset_embeddings(&emb, n, d);
        // churn: retire 6, insert 9, update a few
        for id in [2u32, 5, 11, 17, 20, 23] {
            assert!(s.retire_class(id));
        }
        for _ in 0..9 {
            let mut row = vec![0.0f32; d];
            rng.fill_normal(&mut row, 0.5);
            s.insert_class(&row);
        }
        let mut rows = vec![0.0f32; 2 * d];
        rng.fill_normal(&mut rows, 0.5);
        s.update_many(&[1, 25], &rows);

        // the canonical gather the compactor will rebuild from
        let (ids, flat) = s.live_classes();
        s.compact();

        // a from-scratch streaming sampler over the same (dense) layout:
        // identical arena bits ⇒ identical draws and q, bit for bit
        let mut fresh = StreamingKernelSampler::new(QuadraticMap::new(d, ALPHA), ids.len(), Some(4))
            .with_policy(CompactionPolicy::manual());
        fresh.reset_embeddings(&flat, ids.len(), d);
        let h = vec![0.9f32, -0.2, 0.4];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let (mut a, mut b) = (Sample::default(), Sample::default());
        for seed in 0..20u64 {
            s.sample(&input, 16, &mut Rng::new(seed), &mut a).unwrap();
            fresh.sample(&input, 16, &mut Rng::new(seed), &mut b).unwrap();
            let mapped: Vec<u32> = b.classes.iter().map(|&c| ids[c as usize]).collect();
            assert_eq!(a.classes, mapped, "slot→id mapping drifted");
            assert_eq!(a.q, b.q, "q must be bitwise equal to the rebuild");
        }
        for (slot, &gid) in ids.iter().enumerate() {
            assert_eq!(s.prob(&input, gid), fresh.prob(&input, slot as u32));
        }
    }

    #[test]
    fn policy_auto_compacts_on_cap_and_tombstone_fraction() {
        let (n, d) = (16usize, 2usize);
        let mut rng = Rng::new(66);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let policy = CompactionPolicy { memtable_cap: 4, max_tombstone_frac: 0.25 };
        let mut s =
            StreamingKernelSampler::new(QuadraticMap::new(d, ALPHA), n, Some(4)).with_policy(policy);
        s.reset_embeddings(&emb, n, d);
        for _ in 0..4 {
            let mut row = vec![0.0f32; d];
            rng.fill_normal(&mut row, 0.5);
            s.insert_class(&row);
        }
        assert_eq!(s.obs().compactions(), 1, "memtable cap must trigger a fold");
        assert_eq!(s.memtable_len(), 0);
        assert_eq!(s.live_len(), 20);
        // tombstone fraction: 20 arena classes, retiring 6 crosses 25%
        for id in 0..6u32 {
            s.retire_class(id);
        }
        assert_eq!(s.obs().compactions(), 2, "tombstone fraction must trigger a fold");
        assert_eq!(s.tombstone_len(), 0);
        assert_eq!(s.live_len(), 14);
    }

    #[test]
    fn rff_streaming_draws_live_classes_with_positive_q() {
        let (n, d) = (20usize, 4usize);
        let mut rng = Rng::new(77);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.4);
        let map = PositiveRffMap::new(RffConfig::new(d, RFF_BUILD_SEED));
        let mut s = StreamingKernelSampler::new(map, n, Some(4))
            .with_policy(CompactionPolicy::manual());
        s.reset_embeddings(&emb, n, d);
        assert_eq!(s.name(), "rff-streaming");
        s.retire_class(3);
        let mut row = vec![0.0f32; d];
        rng.fill_normal(&mut row, 0.4);
        let id = s.insert_class(&row);
        assert_eq!(id, 20);
        let h = vec![0.2f32, -0.5, 0.8, 0.1];
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        s.sample(&input, 64, &mut rng, &mut out).unwrap();
        assert!(out.classes.contains(&id) || !out.classes.contains(&3));
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            assert_ne!(c, 3, "tombstoned class drawn");
            assert!(s.is_live(c));
            assert!(q > 0.0 && q.is_finite());
        }
    }
}
