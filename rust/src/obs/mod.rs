//! Unified telemetry: atomic metrics registry, spans, and online
//! sampler-quality monitors.
//!
//! The paper's central claim is a bias/sample-size/speed trade-off
//! ("kernel based sampling results in low bias with few samples"); this
//! module is the layer that makes the trade-off *observable while the
//! system runs* rather than only in offline benches:
//!
//! * [`histogram`] — log-scale-bucketed latency/size histograms with a
//!   lock-free hot path (one relaxed `fetch_add` into an `AtomicU64`
//!   bucket array per record) and exact snapshot/merge semantics, so the
//!   same blocked-accumulation discipline as `ops/` holds: hot threads
//!   only ever touch atomics, aggregation happens on cold reader paths.
//! * [`registry`] — a **global-free** [`MetricsRegistry`]: no statics, no
//!   `lazy_static`; owners construct a registry, components hand their
//!   already-live atomic cells to it under stable names, and exports read
//!   a consistent [`MetricsSnapshot`]. Registering is mutex-guarded (cold,
//!   startup-only); recording never takes a lock.
//! * [`span`] — RAII phase timers ([`span()`]) recording elapsed seconds
//!   into a histogram on drop; the re-implemented
//!   [`crate::util::stats::PhaseTimes`] is a thin adapter over these
//!   cells, so trainer phase reports and telemetry exports share storage.
//! * [`monitor`] — online sampler-quality estimators over eq. (2)
//!   importance weights: a reservoir-based streaming TV-to-exact-softmax
//!   estimator and an effective-sample-size (ESS) gauge, run on a
//!   configurable stride so steady-state overhead stays bounded (the
//!   `obs_overhead` bench pins < 3% at the default stride).
//! * [`export`] — the two export paths: `kind: "telemetry"` JSONL records
//!   for the coordinator's `MetricsSink` stream, and Prometheus-style
//!   text exposition (`kss serve --metrics-path`, load-test exit).
//!
//! Every algorithmic piece (bucket index/merge/quantile, TV/ESS) has a
//! line-for-line Python port in `python/tools/obs_port_check.py`, run in
//! the no-toolchain CI job against the same pinned vectors as the unit
//! tests here.

pub mod export;
pub mod histogram;
pub mod monitor;
pub mod registry;
pub mod span;

pub use histogram::{Histogram, HistogramSnapshot};
pub use monitor::{ess_fraction, tv_from_pairs, QualityMonitor};
pub use registry::{Counter, Gauge, MetricKind, MetricsRegistry, MetricsSnapshot};
pub use span::{span, Span};
