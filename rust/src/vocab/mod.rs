//! Streaming vocabulary: LSM-style online class insertion and retirement
//! under live traffic.
//!
//! The kernel-tree arena ([`crate::sampler::kernel::tree`]) is fixed-C at
//! build time, but real catalogs churn. This subsystem makes the class
//! set dynamic without an O(C) rebuild per change, while every drawn
//! sample still carries an exact eq. (2) proposal probability q:
//!
//! ```text
//!                       ┌────────────────────────────┐
//!        draw tier ∝ M  │  mass router (2-tier CDF)  │
//!                       └──────┬──────────────┬──────┘
//!                 M_arena−M_tomb│             │M_mem
//!                ┌─────────────▼──┐   ┌───────▼────────┐
//!                │  arena tier    │   │ memtable tier  │
//!                │ immutable tree │   │ flat CDF over  │
//!                │ snapshot, with │   │ recent inserts │
//!                │ tombstone mask │   │ (mutable)      │
//!                └────────────────┘   └────────────────┘
//! ```
//!
//! * **Inserts** land in the [`memtable::Memtable`] — a small flat-CDF
//!   sampler whose per-example weights are kernel scores recomputed from
//!   the current rows, so an update is visible to the very next draw.
//! * **Retirements** of arena classes enter a [`memtable::TombstoneSet`]:
//!   the quadratic kernel `αo²+1 ≥ 1` means a class can never be silenced
//!   through its embedding, so tombstoned mass is *subtracted* from the
//!   arena tier's partition total and draws landing on a tombstoned slot
//!   are rejected and redrawn (memtable-resident classes just leave the
//!   memtable).
//! * The **tier router** draws a tier proportional to its aggregated
//!   kernel mass and multiplies probabilities — the same algebra as the
//!   shard router in [`crate::serve::shard`], so the composite
//!   `q = (M_tier/ΣM)·q_tier = K(h,w)/ΣM` equals a single tree over the
//!   live union (property-tested to ≤ 1e-12 relative).
//! * A **compactor** periodically folds the memtable into the arena and
//!   drops tombstones: it gathers the live rows, builds a fresh dense
//!   tree (bitwise-equal to a from-scratch rebuild by construction) and,
//!   on the serve path, hands it to
//!   [`crate::serve::snapshot::TreePublisher::compact_and_publish`] — the
//!   replay log grows a `Compact` barrier record and pre-barrier arenas
//!   leave the reclaim queue.
//!
//! [`streaming::StreamingKernelSampler`] is the self-contained trainer
//! sampler (registry names `quadratic-streaming` / `rff-streaming`);
//! [`publisher::VocabPublisher`] / [`publisher::VocabSnapshotSampler`]
//! split the same machinery into a serve-style writer and wait-free
//! snapshot readers.

pub mod memtable;
pub mod publisher;
pub mod streaming;

pub use memtable::{Memtable, TombstoneSet};
pub use publisher::{VocabPublisher, VocabSnapshot, VocabSnapshotSampler};
pub use streaming::StreamingKernelSampler;

use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry};
use std::sync::Arc;

/// When the streaming layer folds the memtable into the arena.
///
/// Both bounds matter for correctness margins, not just cost: the
/// tombstone fraction caps (a) the expected rejection count per arena
/// draw at `1/(1-frac)` and (b) the cancellation error of the
/// mass-exclusion subtraction `M_arena − M_tomb` (the relative error
/// grows like `ε·M_arena/M_live`, so keeping tombstoned mass a bounded
/// fraction keeps the composite q within the 1e-12 envelope the property
/// tests pin).
#[derive(Clone, Copy, Debug)]
pub struct CompactionPolicy {
    /// Fold once the memtable holds this many classes.
    pub memtable_cap: usize,
    /// Fold once tombstones exceed this fraction of the arena.
    pub max_tombstone_frac: f64,
}

impl Default for CompactionPolicy {
    fn default() -> CompactionPolicy {
        CompactionPolicy { memtable_cap: 256, max_tombstone_frac: 0.25 }
    }
}

impl CompactionPolicy {
    /// Policy that never auto-compacts (tests drive explicit schedules).
    pub fn manual() -> CompactionPolicy {
        CompactionPolicy { memtable_cap: usize::MAX, max_tombstone_frac: f64::INFINITY }
    }

    pub fn should_compact(&self, arena_n: usize, tombstones: usize, memtable: usize) -> bool {
        memtable >= self.memtable_cap
            || (tombstones as f64) > self.max_tombstone_frac * arena_n.max(1) as f64
    }
}

/// Shared telemetry cells for one streaming vocabulary (trainer-side
/// sampler or serve-side publisher). Registered under stable
/// `kss_vocab_*` names; same-name registration aggregates across
/// instances (counters sum, gauges max, histograms merge).
#[derive(Clone, Default)]
pub struct VocabObs {
    /// Classes currently in the memtable tier.
    pub(crate) memtable_size: Arc<Gauge>,
    /// Arena classes currently tombstoned.
    pub(crate) tombstones: Arc<Gauge>,
    /// Wall seconds per compaction (gather + rebuild + swap).
    pub(crate) compaction_seconds: Arc<Histogram>,
    /// Mutating ops (insert/retire/update batches) folded per compaction —
    /// the "lag" between folds.
    pub(crate) compaction_lag_ops: Arc<Histogram>,
    /// Draws routed to the arena tier.
    pub(crate) tier_arena: Arc<Counter>,
    /// Draws routed to the memtable tier.
    pub(crate) tier_memtable: Arc<Counter>,
    /// Arena draws rejected because they landed on a tombstoned slot.
    pub(crate) tombstone_rejects: Arc<Counter>,
    /// Arena draws that exhausted the rejection budget and fell back to a
    /// uniform live-slot scan (signals a violated compaction policy).
    pub(crate) reject_overflows: Arc<Counter>,
    /// Embedding updates dropped because the class is tombstoned or the
    /// id is unknown — the churn-aware `update_many` makes the drop
    /// countable.
    pub(crate) dropped_updates: Arc<Counter>,
    /// Classes inserted / retired over the lifetime.
    pub(crate) inserts: Arc<Counter>,
    pub(crate) retires: Arc<Counter>,
}

impl VocabObs {
    /// Bind every cell to `reg` under the stable `kss_vocab_*` names.
    pub fn register_into(&self, reg: &MetricsRegistry) {
        reg.register_gauge(
            "kss_vocab_memtable_size",
            "classes",
            "vocab",
            "classes currently in the memtable tier",
            Arc::clone(&self.memtable_size),
        );
        reg.register_gauge(
            "kss_vocab_tombstones",
            "classes",
            "vocab",
            "arena classes currently tombstoned",
            Arc::clone(&self.tombstones),
        );
        reg.register_histogram(
            "kss_vocab_compaction_seconds",
            "seconds",
            "vocab",
            "wall seconds per memtable→arena compaction",
            Arc::clone(&self.compaction_seconds),
        );
        reg.register_histogram(
            "kss_vocab_compaction_lag_ops",
            "ops",
            "vocab",
            "mutating ops folded per compaction (lag between folds)",
            Arc::clone(&self.compaction_lag_ops),
        );
        reg.register_counter(
            "kss_vocab_tier_arena_total",
            "draws",
            "vocab",
            "draws routed to the arena tier",
            Arc::clone(&self.tier_arena),
        );
        reg.register_counter(
            "kss_vocab_tier_memtable_total",
            "draws",
            "vocab",
            "draws routed to the memtable tier",
            Arc::clone(&self.tier_memtable),
        );
        reg.register_counter(
            "kss_vocab_tombstone_reject_total",
            "draws",
            "vocab",
            "arena draws rejected on a tombstoned slot and redrawn",
            Arc::clone(&self.tombstone_rejects),
        );
        reg.register_counter(
            "kss_vocab_reject_overflow_total",
            "draws",
            "vocab",
            "arena draws that exhausted the rejection budget",
            Arc::clone(&self.reject_overflows),
        );
        reg.register_counter(
            "kss_vocab_dropped_update_total",
            "updates",
            "vocab",
            "embedding updates dropped (tombstoned or unknown class id)",
            Arc::clone(&self.dropped_updates),
        );
        reg.register_counter(
            "kss_vocab_insert_total",
            "classes",
            "vocab",
            "classes inserted over the lifetime",
            Arc::clone(&self.inserts),
        );
        reg.register_counter(
            "kss_vocab_retire_total",
            "classes",
            "vocab",
            "classes retired over the lifetime",
            Arc::clone(&self.retires),
        );
    }

    pub fn compactions(&self) -> u64 {
        self.compaction_seconds.count()
    }

    pub fn tier_arena_total(&self) -> u64 {
        self.tier_arena.get()
    }

    pub fn tier_memtable_total(&self) -> u64 {
        self.tier_memtable.get()
    }

    pub fn dropped_update_total(&self) -> u64 {
        self.dropped_updates.get()
    }

    pub fn insert_total(&self) -> u64 {
        self.inserts.get()
    }

    pub fn retire_total(&self) -> u64 {
        self.retires.get()
    }
}
