"""Rust tokenizer + lightweight parser: the shared frontend of pallas-lint.

One pass produces, per source file:

* a token stream (idents, numbers, strings, lifetimes, punctuation,
  comments) with line numbers — string literals (incl. raw/byte strings),
  char literals, lifetimes and nested block comments are lexed exactly so
  no rule can be fooled by `"unsafe"` inside a string or a `// panic!`
  comment;
* delimiter-balance errors (the original `lexcheck.py` check — that
  script is now a thin shim over this module);
* lightweight structure: `fn` spans (name + body extent via brace
  matching), `#[cfg(test)] mod` spans, and brace-matched block extraction
  helpers the rules build scope tracking on.

This is intentionally NOT a full Rust parser: every rule works on tokens
plus brace structure, which is robust to the subset of Rust this repo
uses and cheap enough to run on every file in CI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

# Token kinds
IDENT = "ident"
NUM = "num"
STR = "str"
CHAR = "char"
LIFETIME = "lifetime"
PUNCT = "punct"
COMMENT = "comment"

_IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
_IDENT_CONT = _IDENT_START | set("0123456789")
_DIGITS = set("0123456789")

OPEN = {"(": ")", "[": "]", "{": "}"}
CLOSE = {")": "(", "]": "[", "}": "{"}


@dataclass
class Token:
    kind: str
    text: str
    line: int

    def __repr__(self) -> str:  # compact for test failures
        return f"{self.kind}:{self.text!r}@{self.line}"


@dataclass
class Function:
    """A `fn` item: header + brace-matched body extent (token indices are
    into the *code* token stream of the owning SourceFile)."""

    name: str
    start_line: int
    end_line: int
    # index of the body-opening `{` and its matching `}` in sf.code
    body_open: int
    body_close: int


def tokenize(src: str, path: str = "<mem>"):
    """Lex `src` into (tokens, balance_errors).

    `balance_errors` is the list of human-readable delimiter problems the
    original lexcheck reported — empty for well-formed sources.
    """
    toks: list[Token] = []
    errs: list[str] = []
    stack: list[tuple[str, int]] = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # line comment (doc comments included)
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            toks.append(Token(COMMENT, src[i:j], line))
            i = j
            continue
        # block comment (nested)
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            start, start_line, depth, i = i, line, 1, i + 2
            while i < n and depth:
                if src[i] == "\n":
                    line += 1
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    i += 1
            toks.append(Token(COMMENT, src[start:i], start_line))
            continue
        # raw string r"..." / r#"..."# / br#"..."#
        if c in "rb":
            j = i
            if src[j] == "b":
                j += 1
            if j < n and src[j] == "r":
                k = j + 1
                hashes = 0
                while k < n and src[k] == "#":
                    hashes += 1
                    k += 1
                if k < n and src[k] == '"':
                    end = '"' + "#" * hashes
                    e = src.find(end, k + 1)
                    if e < 0:
                        errs.append(f"{path}:{line}: unterminated raw string")
                        return toks, errs
                    start_line = line
                    line += src.count("\n", i, e)
                    toks.append(Token(STR, src[i : e + len(end)], start_line))
                    i = e + len(end)
                    continue
        # plain string (b"..." too)
        if c == '"' or (c == "b" and i + 1 < n and src[i + 1] == '"'):
            start, start_line = i, line
            i += 2 if c == "b" else 1
            while i < n:
                if src[i] == "\\":
                    i += 2
                    continue
                if src[i] == "\n":
                    line += 1
                if src[i] == '"':
                    i += 1
                    break
                i += 1
            toks.append(Token(STR, src[start:i], start_line))
            continue
        # char literal vs lifetime
        if c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                e = src.find("'", i + 2)
                j = (e + 1) if e > 0 else i + 2
                toks.append(Token(CHAR, src[i:j], line))
                i = j
                continue
            if i + 2 < n and src[i + 2] == "'":
                toks.append(Token(CHAR, src[i : i + 3], line))
                i += 3
                continue
            j = i + 1
            while j < n and src[j] in _IDENT_CONT:
                j += 1
            toks.append(Token(LIFETIME, src[i:j], line))
            i = j
            continue
        # identifier / keyword
        if c in _IDENT_START:
            j = i + 1
            while j < n and src[j] in _IDENT_CONT:
                j += 1
            toks.append(Token(IDENT, src[i:j], line))
            i = j
            continue
        # number: digits, optional fraction/exponent/suffix (0.0f64, 1e-9,
        # 0xFF, 1_000). A trailing `.` followed by an ident is a method
        # call on an integer literal (`0.max(..)`) — leave the dot.
        if c in _DIGITS:
            j = i + 1
            while j < n and (src[j] in _IDENT_CONT):
                j += 1
            if j < n and src[j] == "." and j + 1 < n and src[j + 1] in _DIGITS:
                j += 1
                while j < n and src[j] in _IDENT_CONT:
                    j += 1
            elif j < n and src[j] == "." and not (j + 1 < n and src[j + 1] in _IDENT_START):
                j += 1  # `1.` style float
            # exponent sign: `1e-9` lexes as one number
            if j < n and src[j] in "+-" and src[j - 1] in "eE" and src[i] != "0":
                j += 1
                while j < n and src[j] in _IDENT_CONT:
                    j += 1
            toks.append(Token(NUM, src[i:j], line))
            i = j
            continue
        # delimiters: balance-checked, emitted as punct
        if c in OPEN:
            stack.append((c, line))
            toks.append(Token(PUNCT, c, line))
            i += 1
            continue
        if c in CLOSE:
            if not stack:
                errs.append(f"{path}:{line}: unmatched '{c}'")
            elif stack[-1][0] != CLOSE[c]:
                o, ol = stack[-1]
                errs.append(f"{path}:{line}: '{c}' closes '{o}' opened at line {ol}")
                stack.pop()
            else:
                stack.pop()
            toks.append(Token(PUNCT, c, line))
            i += 1
            continue
        toks.append(Token(PUNCT, c, line))
        i += 1
    for o, ol in stack:
        errs.append(f"{path}:{ol}: unclosed '{o}'")
    return toks, errs


def balance_errors(src: str, path: str) -> list[str]:
    """Delimiter-balance check only — the original lexcheck behaviour."""
    return tokenize(src, path)[1]


class SourceFile:
    """A lexed Rust source with the structure helpers rules need."""

    def __init__(self, path: str, src: str):
        self.path = path  # repo-relative, forward slashes
        self.src = src
        self.lines = src.split("\n")
        self.tokens, self.balance = tokenize(src, path)
        # code stream: comments stripped (rules that need comments — the
        # unsafe audit — read self.lines / self.tokens directly)
        self.code: list[Token] = [t for t in self.tokens if t.kind != COMMENT]
        self._test_spans: Optional[list[tuple[int, int]]] = None
        self._functions: Optional[list[Function]] = None

    # -- structure ---------------------------------------------------------

    def match_brace(self, open_idx: int) -> int:
        """Index (into self.code) of the `}` matching the `{` at open_idx.
        Returns len(self.code) - 1 when unbalanced (callers treat the rest
        of the file as the block)."""
        depth = 0
        for j in range(open_idx, len(self.code)):
            t = self.code[j]
            if t.kind == PUNCT and t.text == "{":
                depth += 1
            elif t.kind == PUNCT and t.text == "}":
                depth -= 1
                if depth == 0:
                    return j
        return len(self.code) - 1

    def test_spans(self) -> list[tuple[int, int]]:
        """Line spans (start, end inclusive) of `#[cfg(test)] mod` blocks
        and `#[test]`-attributed items."""
        if self._test_spans is not None:
            return self._test_spans
        spans: list[tuple[int, int]] = []
        code = self.code
        i = 0
        while i < len(code):
            t = code[i]
            if t.kind == PUNCT and t.text == "#":
                # match #[cfg(test)] or #[test]
                texts = [c.text for c in code[i : i + 7]]
                is_cfg_test = texts[:6] == ["#", "[", "cfg", "(", "test", ")"]
                is_test_attr = texts[:4] == ["#", "[", "test", "]"]
                if is_cfg_test or is_test_attr:
                    # find the next `{` and take its block
                    j = i
                    while j < len(code) and not (
                        code[j].kind == PUNCT and code[j].text == "{"
                    ):
                        j += 1
                    if j < len(code):
                        close = self.match_brace(j)
                        spans.append((t.line, code[close].line))
                        i = close + 1
                        continue
            i += 1
        self._test_spans = spans
        return spans

    def in_test(self, line: int) -> bool:
        return any(lo <= line <= hi for lo, hi in self.test_spans())

    def functions(self) -> list[Function]:
        """Every `fn` item (including nested/impl fns and fns in test
        mods) with its brace-matched body extent."""
        if self._functions is not None:
            return self._functions
        fns: list[Function] = []
        code = self.code
        i = 0
        while i < len(code):
            t = code[i]
            if t.kind == IDENT and t.text == "fn":
                if i + 1 < len(code) and code[i + 1].kind == IDENT:
                    name = code[i + 1].text
                    # body `{` is the first `{` with (), [] and <> header
                    # nesting closed; a `;` first means a trait/extern
                    # declaration with no body
                    depth_par = 0
                    j = i + 2
                    body_open = -1
                    while j < len(code):
                        c = code[j]
                        if c.kind == PUNCT:
                            if c.text in "([":
                                depth_par += 1
                            elif c.text in ")]":
                                depth_par -= 1
                            elif c.text == ";" and depth_par == 0:
                                break
                            elif c.text == "{" and depth_par == 0:
                                body_open = j
                                break
                        j += 1
                    if body_open >= 0:
                        close = self.match_brace(body_open)
                        fns.append(
                            Function(
                                name=name,
                                start_line=t.line,
                                end_line=code[close].line,
                                body_open=body_open,
                                body_close=close,
                            )
                        )
            i += 1
        self._functions = fns
        return fns

    def function_at(self, line: int) -> Optional[Function]:
        """Innermost function containing `line` (functions() returns outer
        fns before the nested ones they contain; last match = innermost)."""
        hit = None
        for f in self.functions():
            if f.start_line <= line <= f.end_line:
                if hit is None or f.start_line >= hit.start_line:
                    hit = f
        return hit

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def window(self, line: int, before: int = 0, after: int = 0) -> str:
        lo = max(1, line - before)
        hi = min(len(self.lines), line + after)
        return "\n".join(self.lines[lo - 1 : hi])


def snippet(sf: SourceFile, line: int, width: int = 160) -> str:
    s = sf.line_text(line).strip()
    return s[:width]


_WS = re.compile(r"\s+")


def normalize(code_line: str) -> str:
    """Whitespace-insensitive form of a line, for stable fingerprints."""
    return _WS.sub(" ", code_line.strip())
