//! The stage-overlapped training engine behind [`crate::coordinator::Trainer`].
//!
//! The paper's training step is inherently staged — encode `h`, draw `m`
//! negatives with the eq. (2) corrections, fused sampled-softmax device
//! step, Fig. 1(b) tree update + publish — and the stages have exactly one
//! cross-step dependency that matters: step `t`'s *device math* needs step
//! `t`'s negatives, but step `t+1`'s *negatives* only need a proposal
//! distribution q, and eq. (2) stays an exact estimator for **any** q as
//! long as the corrections `ln(m·q)` use the q actually sampled from. That
//! freedom is what this module exploits.
//!
//! ```text
//! depth 1 (sequential; bitwise the legacy loop)
//!   main:    [enc t][sample t][device t][apply t][publish t][enc t+1]...
//!
//! depth 2 (one step of lookahead)
//!   main:    [enc t+1]          [device t][apply t]  [enc t+2]  [device t+1]...
//!   worker:           [sample t+1]        [publish t]        [sample t+2]...
//! ```
//!
//! * The **coordinator thread** keeps the PJRT engine (it is not `Sync`)
//!   and runs encode, the fused device step, and the host-mirror patch.
//! * One **pipeline worker** runs the sampling fan-out (which itself fans
//!   out over the sampler layer's threadpool) and the tree
//!   update+publish, in strict FIFO order.
//!
//! FIFO is the determinism argument: `sample t+1` is enqueued *before*
//! `publish t`, so it always reads the generation published by step `t−1`
//! — one step staler than the sequential loop, never a race. The q it
//! reports is the exact probability under that pinned generation, so the
//! corrections match the draws and the estimator stays exact; only the
//! *adaptivity* of q lags one step. `publish t` completes before
//! `sample t+2` begins (same queue), so staleness is exactly one step, for
//! any thread count. Seeds are drawn from the trainer RNG in step order at
//! schedule time, giving depth 2 the same seed sequence as depth 1.
//!
//! Publishing rides the worker too ("publish moves off the critical
//! path"): the coordinator enqueues the step's changed rows and starts the
//! next device step immediately; [`PipelineDriver::drain`] collects the
//! hidden wall time for [`crate::util::stats::PhaseTimes`].

use crate::runtime::manifest::{ModelSpec, OpSpec};
use crate::sampler::{BatchSampleInput, Sample, Sampler};
use crate::serve::ShardPublisher;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A publisher shared between the coordinator (enable-serving, stats,
/// depth-1 inline publish) and the pipeline worker (depth-2 offloaded
/// publish). The mutex is uncontended by construction: at depth 2 only the
/// worker publishes during an epoch.
pub type SharedPublisher = Arc<Mutex<Box<dyn ShardPublisher>>>;

/// Everything one step's sampling stage needs, owned — so it can cross to
/// the pipeline worker without borrowing the trainer. The model-dependent
/// tensors (`h`, `logits`) were produced by the coordinator's encode stage
/// at schedule time; at depth 2 they are one device step stale, which is
/// exactly the documented q-staleness.
pub struct SampleTask {
    /// Step index (for reporting; the schedule is FIFO regardless).
    pub step: usize,
    /// The trainer-RNG seed for this step's `row_rng` streams, drawn in
    /// step order at schedule time.
    pub seed: u64,
    pub n: usize,
    pub d: usize,
    pub n_classes: usize,
    pub m: usize,
    pub threads: usize,
    /// Query embeddings (n × d) from the encode artifact.
    pub h: Option<Vec<f32>>,
    /// Full logit rows (n × n_classes) from the score_all artifact.
    pub logits: Option<Vec<f32>>,
    /// Previous-token context (LM datasets).
    pub prev: Option<Vec<u32>>,
    /// Reused output buffer (from [`StepScratch::take_rows`]).
    pub rows: Vec<Sample>,
}

/// What the sampling stage hands back to the device stage.
pub struct SampleOutcome {
    pub step: usize,
    /// One slot per example: `m` (class, q) draws.
    pub rows: Vec<Sample>,
    /// Wall seconds the fan-out took (hidden at depth 2).
    pub sample_s: f64,
    /// Snapshot generation the draws were pinned to (`None` for samplers
    /// that own their state) — the tag that proves the eq. (2) corrections
    /// came from the generation actually sampled.
    pub generation: Option<u64>,
    /// Sampling errors surface here, at collect time, on the coordinator.
    pub result: Result<()>,
}

/// Run one sampling stage: re-pin the sampler's snapshot generation (the
/// deterministic refresh point — see the module docs), then draw every
/// row's negatives. Shared verbatim by the depth-1 inline path and the
/// pipeline worker, so the two depths execute identical sampling code.
pub fn run_sample_task(sampler: &dyn Sampler, mut task: SampleTask) -> SampleOutcome {
    let t0 = Instant::now();
    sampler.refresh_snapshots();
    let generation = sampler.pinned_generation();
    if task.rows.len() != task.n {
        task.rows.resize_with(task.n, Sample::default);
    }
    let inputs = BatchSampleInput {
        n: task.n,
        d: task.d,
        n_classes: task.n_classes,
        h: task.h.as_deref(),
        logits: task.logits.as_deref(),
        prev: task.prev.as_deref(),
        threads: task.threads,
    };
    let result = sampler.sample_batch(&inputs, task.m, task.seed, &mut task.rows);
    SampleOutcome {
        step: task.step,
        rows: task.rows,
        sample_s: t0.elapsed().as_secs_f64(),
        generation,
        result,
    }
}

enum WorkItem {
    Sample(Arc<dyn Sampler>, SampleTask),
    Publish(SharedPublisher, Vec<usize>, Vec<f32>),
}

/// What a finished publish sends back: its wall seconds plus the rows
/// buffer, returned for reuse (the classes vec was a fresh allocation the
/// host mirror produced anyway; it dies with the worker).
type PublishDone = (f64, Vec<f32>);

/// The pipeline worker thread: samples and publishes in strict FIFO order
/// (the determinism contract of the module docs).
struct Worker {
    tx: Option<mpsc::Sender<WorkItem>>,
    sample_rx: mpsc::Receiver<SampleOutcome>,
    publish_rx: mpsc::Receiver<PublishDone>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    fn spawn() -> Worker {
        let (tx, rx) = mpsc::channel::<WorkItem>();
        let (sample_tx, sample_rx) = mpsc::channel();
        let (publish_tx, publish_rx) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("kss-pipeline".into())
            .spawn(move || {
                while let Ok(item) = rx.recv() {
                    match item {
                        WorkItem::Sample(sampler, task) => {
                            let outcome = run_sample_task(sampler.as_ref(), task);
                            if sample_tx.send(outcome).is_err() {
                                return;
                            }
                        }
                        WorkItem::Publish(publisher, classes, rows_flat) => {
                            let t0 = Instant::now();
                            publisher
                                .lock()
                                .expect("publisher poisoned")
                                .update_and_publish_rows(&classes, &rows_flat);
                            if publish_tx.send((t0.elapsed().as_secs_f64(), rows_flat)).is_err() {
                                return;
                            }
                        }
                    }
                }
            })
            .expect("spawn pipeline worker");
        Worker { tx: Some(tx), sample_rx, publish_rx, handle: Some(handle) }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        // close the queue; the worker finishes what it has and exits
        drop(self.tx.take());
        if let Some(handle) = self.handle.take() {
            if let Err(payload) = handle.join() {
                // propagate a worker panic — unless this drop is itself
                // part of an unwind (a second panic would abort and eat
                // the original message)
                if !std::thread::panicking() {
                    std::panic::resume_unwind(payload);
                }
            }
        }
    }
}

/// Schedules sampling and publishing around the coordinator's device
/// steps. Depth 1 runs every stage inline in legacy order; depth ≥ 2 keeps
/// one sampling stage in flight on the worker and offloads publishes
/// behind it.
pub struct PipelineDriver {
    depth: usize,
    worker: Option<Worker>,
    /// Completed outcomes awaiting collection (inline path).
    ready: VecDeque<SampleOutcome>,
    in_flight: usize,
    pending_publishes: usize,
    hidden_publish_s: f64,
    /// Freelist of rows buffers round-tripping through the publish stage
    /// (filled by the caller, consumed by the publish, returned here) —
    /// steady-state publishes allocate nothing for their payload.
    rows_bufs: Vec<Vec<f32>>,
}

impl PipelineDriver {
    /// `depth` 1 = sequential; 2 = one step of lookahead. Deeper lookahead
    /// would add more than one generation of staleness for no extra
    /// overlap (one device stream), so depth is clamped to [1, 2].
    pub fn new(depth: usize) -> PipelineDriver {
        PipelineDriver {
            depth: depth.clamp(1, 2),
            worker: None,
            ready: VecDeque::new(),
            in_flight: 0,
            pending_publishes: 0,
            hidden_publish_s: 0.0,
            rows_bufs: Vec::new(),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Whether sampling overlaps the device step (depth ≥ 2).
    pub fn overlapped(&self) -> bool {
        self.depth > 1
    }

    /// Sampling stages scheduled but not yet collected.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    fn worker(&mut self) -> &Worker {
        if self.worker.is_none() {
            self.worker = Some(Worker::spawn());
        }
        self.worker.as_ref().expect("just spawned")
    }

    /// Schedule one step's sampling. Inline (runs now, on this thread) at
    /// depth 1; enqueued on the worker at depth 2. At most one stage may
    /// be in flight beyond the one being collected.
    pub fn schedule_sample(&mut self, sampler: &Arc<dyn Sampler>, task: SampleTask) {
        debug_assert!(self.in_flight < self.depth, "pipeline overfilled");
        self.in_flight += 1;
        if self.overlapped() {
            let sampler = sampler.clone();
            self.worker()
                .tx
                .as_ref()
                .expect("worker queue open")
                .send(WorkItem::Sample(sampler, task))
                .expect("pipeline worker died");
        } else {
            let outcome = run_sample_task(sampler.as_ref(), task);
            self.ready.push_back(outcome);
        }
    }

    /// Collect the oldest scheduled sampling stage. Returns the outcome
    /// and the seconds this thread blocked waiting for it (the *visible*
    /// part of sampling at depth 2; ~0 when overlap worked).
    pub fn collect_sample(&mut self) -> (SampleOutcome, f64) {
        assert!(self.in_flight > 0, "collect without a scheduled sample");
        self.in_flight -= 1;
        if let Some(outcome) = self.ready.pop_front() {
            return (outcome, 0.0);
        }
        // opportunistically bank finished publish timings first
        self.drain_publish_times(false);
        let t0 = Instant::now();
        let outcome = self
            .worker
            .as_ref()
            .expect("in-flight sample implies a worker")
            .sample_rx
            .recv()
            .expect("pipeline worker died");
        (outcome, t0.elapsed().as_secs_f64())
    }

    /// A rows buffer for the next publish payload (pooled: buffers return
    /// here after the publish consumes them, so steady-state publishes
    /// allocate nothing). Opportunistically banks finished publish
    /// timings.
    pub fn take_rows_buf(&mut self) -> Vec<f32> {
        self.drain_publish_times(false);
        self.rows_bufs.pop().unwrap_or_default()
    }

    /// Return a rows buffer that ended up not being published (e.g. a
    /// sampler-only update with no publisher attached).
    pub fn put_rows_buf(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        if self.rows_bufs.len() < 4 {
            self.rows_bufs.push(buf);
        }
    }

    /// Run a tree update + publish, consuming its payload (`rows_flat`
    /// from [`PipelineDriver::take_rows_buf`]; `classes` as produced by
    /// the host-mirror patch). `offload` false runs it on this thread and
    /// returns the publish seconds for the critical-path book — the only
    /// mode that keeps draws deterministic for callers driving single
    /// steps outside the overlapped schedule. `offload` true (depth-2
    /// train loop only) enqueues it behind the in-flight sampling and
    /// returns `None`; the hidden time is banked and surfaced by
    /// [`PipelineDriver::drain`].
    pub fn schedule_publish(
        &mut self,
        publisher: &SharedPublisher,
        classes: Vec<usize>,
        rows_flat: Vec<f32>,
        offload: bool,
    ) -> Option<f64> {
        if offload && self.overlapped() {
            self.pending_publishes += 1;
            let publisher = publisher.clone();
            self.worker()
                .tx
                .as_ref()
                .expect("worker queue open")
                .send(WorkItem::Publish(publisher, classes, rows_flat))
                .expect("pipeline worker died");
            None
        } else {
            let t0 = Instant::now();
            publisher
                .lock()
                .expect("publisher poisoned")
                .update_and_publish_rows(&classes, &rows_flat);
            let secs = t0.elapsed().as_secs_f64();
            self.put_rows_buf(rows_flat);
            Some(secs)
        }
    }

    fn drain_publish_times(&mut self, block: bool) {
        let Some(worker) = self.worker.as_ref() else { return };
        while self.pending_publishes > 0 {
            let got = if block {
                worker.publish_rx.recv().ok()
            } else {
                worker.publish_rx.try_recv().ok()
            };
            match got {
                Some((secs, buf)) => {
                    self.hidden_publish_s += secs;
                    self.pending_publishes -= 1;
                    if self.rows_bufs.len() < 4 {
                        let mut buf = buf;
                        buf.clear();
                        self.rows_bufs.push(buf);
                    }
                }
                None => break,
            }
        }
    }

    /// Wait for every enqueued publish to land and return the hidden
    /// publish seconds accumulated since the last drain. Call before
    /// reading publisher state (stats, served snapshots) or finishing a
    /// run. No sampling stage may be in flight.
    pub fn drain(&mut self) -> f64 {
        assert_eq!(self.in_flight, 0, "drain with a sampling stage in flight");
        self.drain_publish_times(true);
        debug_assert_eq!(self.pending_publishes, 0);
        std::mem::take(&mut self.hidden_publish_s)
    }
}

/// Reusable per-step host buffers for the sampled training loop. One
/// instance lives in the trainer; every vector keeps its allocation across
/// steps (the sampler layer's `DrawScratch`/`Pool` discipline applied to
/// the coordinator): `neg`/`sub` round-trip through the staging tensors
/// via [`crate::runtime::Tensor::into_i32`]/[`into_f32`], `s_idx` is
/// refilled in place, the `Vec<Sample>` row buffers rotate through a small
/// freelist (two are live at depth 2 — one being drawn into, one being
/// consumed), and the publish payload buffers round-trip through the
/// [`PipelineDriver`]'s own pool (they cross to the worker at depth 2).
///
/// [`into_f32`]: crate::runtime::Tensor::into_f32
#[derive(Default)]
pub struct StepScratch {
    pub neg: Vec<i32>,
    pub sub: Vec<f32>,
    pub s_idx: Vec<i32>,
    row_bufs: Vec<Vec<Sample>>,
}

impl StepScratch {
    /// A row buffer with `n` slots, each with capacity for `m` draws —
    /// pooled, so steady-state steps allocate nothing here.
    pub fn take_rows(&mut self, n: usize, m: usize) -> Vec<Sample> {
        let mut rows = self.row_bufs.pop().unwrap_or_default();
        if rows.len() > n {
            rows.truncate(n);
        }
        while rows.len() < n {
            rows.push(Sample::with_capacity(m));
        }
        rows
    }

    /// Return a row buffer for reuse.
    pub fn put_rows(&mut self, rows: Vec<Sample>) {
        // bound the freelist: the pipeline never has more than two buffers
        // alive (plus slack for callers that drop out mid-step)
        if self.row_bufs.len() < 4 {
            self.row_bufs.push(rows);
        }
    }
}

/// Resolved-op cache: the trainer used to call `spec.op(...)` — a lookup
/// plus a full `OpSpec` clone — on **every** encode/step/eval. Each op is
/// now resolved once and reused for the run (`train_sampled` is keyed by
/// the m it was resolved for, so a config's single m never re-resolves).
#[derive(Default)]
pub struct OpCache {
    pub encode: Option<OpSpec>,
    pub score_all: Option<OpSpec>,
    pub eval_full: Option<OpSpec>,
    pub train_full: Option<OpSpec>,
    pub train_sampled: Option<(usize, OpSpec)>,
}

impl OpCache {
    /// Fill `slot` from the spec if empty. Two-phase on purpose: callers
    /// ensure first, then re-borrow the slot immutably next to the other
    /// trainer fields.
    pub fn ensure(slot: &mut Option<OpSpec>, spec: &ModelSpec, name: &str) -> Result<()> {
        if slot.is_none() {
            *slot = Some(spec.op(name)?.clone());
        }
        Ok(())
    }

    /// Fill the `train_sampled` slot for this m (re-resolving only if m
    /// changed, which a fixed config never does).
    pub fn ensure_train_sampled(&mut self, spec: &ModelSpec, m: usize) -> Result<()> {
        if self.train_sampled.as_ref().is_none_or(|(mm, _)| *mm != m) {
            self.train_sampled = Some((m, spec.train_sampled_op(m)?.clone()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::kernel::QuadraticMap;
    use crate::sampler::UniformSampler;
    use crate::serve::ShardSet;
    use crate::util::rng::Rng;

    fn uniform_task(step: usize, seed: u64, n: usize, m: usize, rows: Vec<Sample>) -> SampleTask {
        SampleTask {
            step,
            seed,
            n,
            d: 0,
            n_classes: 0,
            m,
            threads: 2,
            h: None,
            logits: None,
            prev: None,
            rows,
        }
    }

    #[test]
    fn depth1_runs_inline_and_fifo() {
        let sampler: Arc<dyn Sampler> = Arc::new(UniformSampler::new(10));
        let mut driver = PipelineDriver::new(1);
        assert!(!driver.overlapped());
        driver.schedule_sample(&sampler, uniform_task(0, 7, 4, 3, Vec::new()));
        let (out, wait) = driver.collect_sample();
        assert_eq!(out.step, 0);
        assert_eq!(wait, 0.0, "inline outcomes are already complete");
        out.result.unwrap();
        assert_eq!(out.rows.len(), 4);
        assert!(out.rows.iter().all(|r| r.classes.len() == 3));
        assert_eq!(driver.drain(), 0.0);
    }

    #[test]
    fn depth2_background_outcome_matches_inline() {
        // same task, same seed: the worker path must produce bit-identical
        // draws to the inline path (they share run_sample_task)
        let sampler: Arc<dyn Sampler> = Arc::new(UniformSampler::new(50));
        let inline = run_sample_task(sampler.as_ref(), uniform_task(3, 0xBEEF, 6, 5, Vec::new()));
        let mut driver = PipelineDriver::new(2);
        assert!(driver.overlapped());
        driver.schedule_sample(&sampler, uniform_task(3, 0xBEEF, 6, 5, Vec::new()));
        let (bg, _) = driver.collect_sample();
        bg.result.unwrap();
        for (a, b) in inline.rows.iter().zip(&bg.rows) {
            assert_eq!(a.classes, b.classes);
            assert_eq!(a.q, b.q);
        }
        driver.drain();
    }

    #[test]
    fn fifo_pins_sample_to_the_generation_before_the_publish() {
        // the staleness contract: a sample enqueued before a publish reads
        // the pre-publish generation; one enqueued after reads the new one
        let (n, d, m) = (32usize, 2usize, 4usize);
        let mut rng = Rng::new(5);
        let mut emb = vec![0.0f32; n * d];
        rng.fill_normal(&mut emb, 0.5);
        let set = ShardSet::new(QuadraticMap::new(d, 100.0), n, 1, None, Some(&emb));
        let sampler_typed = set.snapshot_sampler();
        let sampler: Arc<dyn Sampler> = Arc::new(sampler_typed);
        let publisher: SharedPublisher = Arc::new(Mutex::new(Box::new(set)));
        let mut driver = PipelineDriver::new(2);
        let mut hs = vec![0.0f32; 3 * d];
        rng.fill_normal(&mut hs, 1.0);
        let task = |step: usize, seed: u64, hs: &[f32]| SampleTask {
            step,
            seed,
            n: 3,
            d,
            n_classes: n,
            m,
            threads: 1,
            h: Some(hs.to_vec()),
            logits: None,
            prev: None,
            rows: Vec::new(),
        };
        // sample 0 before any publish: generation 0
        driver.schedule_sample(&sampler, task(0, 1, &hs));
        let (o0, _) = driver.collect_sample();
        o0.result.unwrap();
        assert_eq!(o0.generation, Some(0));
        // enqueue sample 1, then a publish behind it: FIFO means sample 1
        // still sees generation 0 ...
        driver.schedule_sample(&sampler, task(1, 2, &hs));
        let mut new_row = vec![0.0f32; d];
        rng.fill_normal(&mut new_row, 0.9);
        assert!(driver.schedule_publish(&publisher, vec![7], new_row, true).is_none());
        let (o1, _) = driver.collect_sample();
        o1.result.unwrap();
        assert_eq!(o1.generation, Some(0), "sample overtook the publish");
        // ... and a sample enqueued after the publish sees generation 1
        driver.schedule_sample(&sampler, task(2, 3, &hs));
        let (o2, _) = driver.collect_sample();
        o2.result.unwrap();
        assert_eq!(o2.generation, Some(1), "publish not visible to later sample");
        let hidden = driver.drain();
        assert!(hidden >= 0.0);
        assert_eq!(publisher.lock().unwrap().publish_stats().publishes, 1);
    }

    #[test]
    fn depth1_publish_is_inline_and_timed() {
        let (n, d) = (16usize, 2usize);
        let emb = vec![0.05f32; n * d];
        let set = ShardSet::new(QuadraticMap::new(d, 100.0), n, 2, None, Some(&emb));
        let publisher: SharedPublisher = Arc::new(Mutex::new(Box::new(set)));
        let mut driver = PipelineDriver::new(1);
        let secs =
            driver.schedule_publish(&publisher, vec![1, 9], vec![0.1, 0.2, 0.3, 0.4], false);
        assert!(secs.is_some(), "depth 1 publishes on the calling thread");
        assert_eq!(publisher.lock().unwrap().publish_stats().publishes, 2);
        assert_eq!(driver.drain(), 0.0);
        // the payload buffer came back to the pool
        let buf = driver.take_rows_buf();
        assert!(buf.is_empty() && buf.capacity() >= 4, "rows buffer not pooled");
    }

    #[test]
    fn step_scratch_pools_row_buffers() {
        let mut scratch = StepScratch::default();
        let rows = scratch.take_rows(8, 4);
        assert_eq!(rows.len(), 8);
        assert!(rows.iter().all(|r| r.classes.capacity() >= 4));
        let ptr = rows.as_ptr();
        scratch.put_rows(rows);
        let again = scratch.take_rows(8, 4);
        assert_eq!(again.as_ptr(), ptr, "row buffer must be reused");
        // resizing keeps the allocation when shrinking
        scratch.put_rows(again);
        let smaller = scratch.take_rows(3, 4);
        assert_eq!(smaller.len(), 3);
    }
}
