//! Benchmark harness (offline replacement for `criterion`).
//!
//! Benches under `benches/` are plain binaries (`harness = false`) that use
//! [`Bencher`] for timed micro/meso benchmarks and print aligned tables with
//! mean/p50/p95 and derived throughput — the same rows the paper's tables
//! and figures report. Figure-level benches (fig2..fig7) train real models
//! and print the loss series; this harness provides their timing and table
//! output too.

use crate::util::json::Value;
use crate::util::stats::Samples;
use std::time::Instant;

/// Bench cost scale, from `KSS_BENCH_SCALE` (default `quick`).
///
/// * `quick` — tiny models / few steps; the whole `cargo bench` suite runs
///   in minutes and checks every figure's *shape*.
/// * `full` — the paper-scale sweeps (10k/100k classes, full m sweep);
///   hours on this single-core testbed. Used to produce EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

pub fn scale() -> Scale {
    match std::env::var("KSS_BENCH_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Quick,
    }
}

/// Open the engine over ./artifacts, or exit 0 with a notice (benches must
/// not fail a fresh checkout that hasn't run `make artifacts`).
pub fn engine_or_exit() -> crate::runtime::Engine {
    match crate::runtime::Engine::new(std::path::Path::new("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping bench: {e:#}\n(run `make artifacts` first)");
            std::process::exit(0);
        }
    }
}

/// One benchmark result row.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub name: String,
    /// Seconds per iteration.
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub iters: usize,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchRow {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|it| it / self.mean_s)
    }
}

/// Timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once this much measuring time has elapsed (seconds).
    pub budget_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, min_iters: 10, max_iters: 1000, budget_s: 2.0 }
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end benches.
    pub fn slow() -> Bencher {
        Bencher { warmup_iters: 1, min_iters: 3, max_iters: 50, budget_s: 5.0 }
    }

    /// Measure `f`, which performs one iteration per call.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchRow {
        self.run_with_items(name, None, move || {
            f();
        })
    }

    /// Measure with a known number of logical items per iteration (for
    /// throughput rows, e.g. samples drawn per call).
    pub fn run_with_items(
        &self,
        name: &str,
        items_per_iter: Option<f64>,
        mut f: impl FnMut(),
    ) -> BenchRow {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Samples::new();
        let t_start = Instant::now();
        let mut iters = 0;
        while iters < self.min_iters
            || (iters < self.max_iters && t_start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        BenchRow {
            name: name.to_string(),
            mean_s: samples.mean(),
            p50_s: samples.p50(),
            p95_s: samples.p95(),
            iters,
            items_per_iter,
        }
    }
}

/// Pretty-print a group of rows as an aligned table.
pub fn print_table(title: &str, rows: &[BenchRow]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>8} {:>14}",
        "benchmark", "mean", "p50", "p95", "iters", "throughput"
    );
    for r in rows {
        let tput = r
            .throughput()
            .map(|t| format_throughput(t))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8} {:>14}",
            r.name,
            format_time(r.mean_s),
            format_time(r.p50_s),
            format_time(r.p95_s),
            r.iters,
            tput
        );
    }
}

/// Human time formatting (s/ms/µs/ns).
pub fn format_time(s: f64) -> String {
    if !s.is_finite() {
        "n/a".to_string()
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

fn format_throughput(t: f64) -> String {
    if t >= 1e9 {
        format!("{:.2} G/s", t / 1e9)
    } else if t >= 1e6 {
        format!("{:.2} M/s", t / 1e6)
    } else if t >= 1e3 {
        format!("{:.2} K/s", t / 1e3)
    } else {
        format!("{t:.2} /s")
    }
}

/// Print a speedup line comparing a contender row against a baseline
/// (used by the batched-vs-per-example sampling series).
pub fn print_speedup(label: &str, baseline: &BenchRow, contender: &BenchRow) {
    if contender.mean_s > 0.0 && baseline.mean_s.is_finite() {
        println!(
            "speedup {label}: {:.2}x  ({} -> {})",
            baseline.mean_s / contender.mean_s,
            format_time(baseline.mean_s),
            format_time(contender.mean_s)
        );
    }
}

/// Print a labeled data series (epoch, value) — the figure benches emit the
/// paper's loss-vs-epoch curves in this form so they can be plotted or
/// diffed directly.
pub fn print_series(label: &str, points: &[(f64, f64)]) {
    println!("series {label}");
    for (x, y) in points {
        println!("  {x:.4}\t{y:.6}");
    }
}

/// One row as a JSON object (seconds; throughput in items/s when known).
fn row_to_json(r: &BenchRow) -> Value {
    let mut pairs = vec![
        ("name", Value::str(&r.name)),
        ("mean_s", Value::num(r.mean_s)),
        ("p50_s", Value::num(r.p50_s)),
        ("p95_s", Value::num(r.p95_s)),
        ("iters", Value::num(r.iters as f64)),
    ];
    if let Some(t) = r.throughput() {
        pairs.push(("throughput_per_s", Value::num(t)));
    }
    Value::object(pairs)
}

/// Serialize bench tables to the machine-readable result format written by
/// [`write_json`]: `{"bench": label, "scale": ..., "tables": [{"title",
/// "rows": [...]}]}`.
pub fn tables_to_json(label: &str, tables: &[(&str, &[BenchRow])]) -> Value {
    Value::object(vec![
        ("bench", Value::str(label)),
        (
            "scale",
            Value::str(match scale() {
                Scale::Quick => "quick",
                Scale::Full => "full",
            }),
        ),
        (
            "tables",
            Value::Array(
                tables
                    .iter()
                    .map(|(title, rows)| {
                        Value::object(vec![
                            ("title", Value::str(title)),
                            ("rows", Value::Array(rows.iter().map(row_to_json).collect())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write `BENCH_<label>.json` next to the printed tables so the perf
/// trajectory is diffable across PRs. Destination directory comes from
/// `KSS_BENCH_JSON_DIR` (default: the working directory — the repo root
/// under `cargo bench`). A write failure is reported but never fails the
/// bench itself.
pub fn write_json(label: &str, tables: &[(&str, &[BenchRow])]) {
    write_json_value(label, &tables_to_json(label, tables));
}

/// [`write_json`] for benches whose result rows are not timing-shaped
/// (e.g. the bias/TV tables of `ablation_rff_dim`): same destination rule
/// (`KSS_BENCH_JSON_DIR`), same never-fail contract, caller-supplied
/// document.
pub fn write_json_value(label: &str, doc: &Value) {
    let dir = std::env::var("KSS_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{label}.json"));
    match std::fs::write(&path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_counts() {
        let b = Bencher { warmup_iters: 1, min_iters: 5, max_iters: 10, budget_s: 0.05 };
        let mut count = 0usize;
        let row = b.run("noop", || {
            count += 1;
            std::hint::black_box(count);
        });
        assert!(row.iters >= 5 && row.iters <= 10);
        assert_eq!(count, row.iters + 1); // + warmup
        assert!(row.mean_s >= 0.0 && row.p95_s >= row.p50_s * 0.5);
    }

    #[test]
    fn throughput_derived() {
        let b = Bencher { warmup_iters: 0, min_iters: 3, max_iters: 3, budget_s: 0.01 };
        let row = b.run_with_items("items", Some(100.0), || {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        let t = row.throughput().unwrap();
        assert!(t > 1_000.0 && t < 2_000_000.0, "throughput {t}");
    }

    #[test]
    fn json_emission_roundtrips() {
        let rows = vec![BenchRow {
            name: "draw n=1000".into(),
            mean_s: 1.5e-4,
            p50_s: 1.4e-4,
            p95_s: 2.0e-4,
            iters: 42,
            items_per_iter: Some(32.0),
        }];
        let doc = tables_to_json("sampling", &[("draws", &rows)]);
        let parsed = crate::util::json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "sampling");
        let tables = parsed.get("tables").unwrap().as_array().unwrap();
        let row = &tables[0].get("rows").unwrap().as_array().unwrap()[0];
        assert_eq!(row.get("name").unwrap().as_str().unwrap(), "draw n=1000");
        assert!((row.get("mean_s").unwrap().as_f64().unwrap() - 1.5e-4).abs() < 1e-12);
        let tput = row.get("throughput_per_s").unwrap().as_f64().unwrap();
        assert!((tput - 32.0 / 1.5e-4).abs() < 1e-6 * tput);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(format_time(2.5), "2.500 s");
        assert_eq!(format_time(0.0025), "2.500 ms");
        assert_eq!(format_time(2.5e-6), "2.500 µs");
        assert!(format_time(3e-9).ends_with("ns"));
    }
}
