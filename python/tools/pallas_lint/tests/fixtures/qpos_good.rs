// pallas-lint fixture — must NOT trip QPOS: one function per accepted
// guard idiom.

/// Guard 1: the denominator is clamped on the division statement.
pub fn clamped(k: f64, total: f64) -> f64 {
    k / total.max(f64::MIN_POSITIVE)
}

/// Guard 2: the divisor is checked positive-and-finite just above.
pub fn checked(k: f64, total: f64) -> f64 {
    if total > 0.0 && total.is_finite() {
        k / total
    } else {
        0.0
    }
}

/// Guard 3: the quotient is validated immediately after the division.
pub fn validated(k: f64, total: f64) -> f64 {
    let q = k / total;
    if q > 0.0 && q.is_finite() {
        q
    } else {
        f64::MIN_POSITIVE
    }
}

/// Divisors that are not mass-like are out of scope for this rule.
pub fn plain_average(sum: f64, len: f64) -> f64 {
    sum / len
}
