//! Offline shim for the subset of the `anyhow` crate this workspace uses.
//!
//! The build image has no crates.io access, so this path dependency stands in
//! for the real `anyhow`. It implements exactly the surface the `kss` crate
//! consumes:
//!
//! * [`Error`] — a message plus a cause chain (`Display` prints the
//!   outermost message, `{:#}` prints the whole chain, `Debug` prints a
//!   `Caused by:` listing, matching anyhow's conventions);
//! * [`Result<T>`] with the `Error` default;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros;
//! * the [`Context`] extension trait on `Result` and `Option`;
//! * a blanket `From<E: std::error::Error>` so `?` lifts std errors.
//!
//! Swap the real crate back in by pointing the workspace dependency at
//! crates.io; no call sites change.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message with an ordered chain of causes (outermost context
/// first, original error last).
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    /// Wrap this error with an outer context message (the previous message
    /// becomes the first cause).
    pub fn context(self, context: impl fmt::Display) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain }
    }

    /// The cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `Display` prints).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(cause) = src {
            chain.push(cause.to_string());
            src = cause.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option` (mirrors `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: usize) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(())
        }
        assert!(fails(2).is_ok());
        assert_eq!(fails(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(fails(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("m={m}", m = 5);
        assert_eq!(e.to_string(), "m=5");
    }
}
