// pallas-lint fixture — MUST trip PANIC. Scanned by the self-tests under
// the rust/src/serve/batcher.rs logical path (a PANIC worker file whose
// `submit`/`next_batch` bodies are also checked for raw indexing).

pub struct B {
    q: std::sync::Mutex<Vec<u32>>,
}

impl B {
    pub fn submit(&self, x: u32) {
        let mut g = self.q.lock().unwrap();
        g.push(x);
    }

    pub fn next_batch(&self, items: &[u32]) -> u32 {
        if items.is_empty() {
            panic!("empty batch");
        }
        items[0]
    }

    pub fn shutdown(&self) {
        let g = self.q.lock().expect("poisoned");
        drop(g);
    }
}
