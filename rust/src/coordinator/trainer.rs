//! The training loop — the paper's procedure, end to end:
//!
//! 1. `encode` (AOT artifact) produces the query embeddings `h` for the
//!    batch (only when the sampler is adaptive; static samplers skip it);
//!    `score_all` produces full logit rows for the exact/oracle samplers.
//! 2. every example's `m` negatives are drawn in parallel (threadpool) from
//!    the configured sampler, together with the eq. (2) corrections
//!    `ln(m q)`;
//! 3. the `train_sampled` artifact performs the fused sampled-softmax
//!    forward/backward (Pallas kernel) + SGD update on-device;
//! 4. the updated output-embedding rows (returned by the artifact for
//!    exactly the sampled classes) patch the host mirror, and **one**
//!    kernel-tree update sweep runs — in the serve-layer publisher, whose
//!    published generation both the training sampler and any online
//!    serving readers draw from (the one-tree contract; see
//!    [`crate::coordinator::pipeline`] and [`crate::serve::SnapshotSampler`]).
//!
//! Stages are scheduled by a [`PipelineDriver`]: depth 1 executes them
//! sequentially (bitwise the pre-pipeline loop); depth 2 runs step `t+1`'s
//! encode + sampling while step `t`'s device execute and publish complete,
//! sampling from a one-generation-stale snapshot with exact q corrections
//! (the module docs of [`crate::coordinator::pipeline`] carry the
//! staleness/exactness argument).
//!
//! The full-softmax baseline (`sampler = "full"`) replaces 1-4 with the
//! `train_full` artifact. Evaluation is always the *full* softmax loss on
//! held-out data — the quantity every figure in the paper plots.

use crate::coordinator::config::{build_dataset, TrainConfig};
use crate::coordinator::metrics::{EvalPoint, MetricsSink};
use crate::coordinator::pipeline::{
    run_sample_task, OpCache, PipelineDriver, SampleOutcome, SampleTask, SharedPublisher,
    StepScratch,
};
use crate::data::{Batch, BatchPrefetcher, Dataset};
use crate::runtime::{Engine, ModelSpec, ParamStore, Tensor};
use crate::sampler::kernel::FeatureMap;
use crate::sampler::rff::{self, PositiveRffMap, RffConfig};
use crate::sampler::{build_sampler, MidxObs, QuadraticMap, Sampler, TwoPassObs};
use crate::serve::{ShardPublisher, ShardSet, SnapshotStore, TreeSnapshot};
use crate::util::rng::{splitmix64, Rng};
use crate::util::stats::{PhaseTimes, Stopwatch};
use crate::util::threadpool::default_threads;
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub final_loss: f64,
    pub best_loss: f64,
    pub curve: Vec<EvalPoint>,
    pub steps: usize,
    /// Mean training loss of the last epoch (sampled objective, *not*
    /// comparable across samplers — the eval curve is).
    pub last_train_loss: f64,
}

/// Drives one run. Owns the parameters, sampler and dataset; borrows the
/// engine (executable caches are shared across runs of the same model).
pub struct Trainer<'e> {
    engine: &'e Engine,
    spec: ModelSpec,
    cfg: TrainConfig,
    pub store: ParamStore,
    /// `Arc` so a background sampling stage can hold the sampler while the
    /// coordinator runs the device step. Mutated (`Arc::get_mut`) only by
    /// legacy samplers that own per-step state — those force depth 1, so
    /// the Arc is unique whenever mutation happens.
    sampler: Option<Arc<dyn Sampler>>,
    dataset: Arc<dyn Dataset>,
    rng: Rng,
    /// Per-phase wall-clock accounting (prefetch/encode/sample/step/update/
    /// publish/eval; overlapped work is booked separately).
    pub phases: PhaseTimes,
    threads: usize,
    step_count: usize,
    /// The single source of kernel-tree truth: a serve-layer [`ShardSet`]
    /// that applies each sampled step's Fig. 1(b) rows once and publishes
    /// the generation both the training sampler and online serving read.
    /// Present whenever the sampler is a kernel-tree kind (unified path)
    /// or serving was enabled; shared with the pipeline worker at depth 2.
    publisher: Option<SharedPublisher>,
    /// Resolved artifact ops (no per-call `spec.op(...)` clone).
    ops: OpCache,
    /// Pooled per-step host buffers.
    scratch: StepScratch,
    driver: PipelineDriver,
}

/// The unified-tree construction: for the kernel-tree sampler kinds the
/// trainer builds the serve-layer [`ShardSet`] — the **one** tree — and a
/// [`crate::serve::SnapshotSampler`] over its publish points. Shard
/// topology mirrors `build_sampler`'s pinned counts exactly (1 unsharded,
/// 4 sharded) so draw streams stay bit-reproducible from (config, seed).
/// Non-tree kinds (flat oracles, exact softmax, static samplers) return
/// `None` and keep their legacy construction.
#[allow(clippy::type_complexity)]
fn snapshot_backed_parts(
    name: &str,
    spec: &ModelSpec,
    w: &[f32],
    pool_factor: f64,
) -> Option<(Arc<dyn Sampler>, SharedPublisher, Option<TwoPassObs>, Option<MidxObs>)> {
    /// How the snapshot adapter routes draws over the published tree.
    enum SnapMode {
        Plain,
        TwoPass,
        Midx,
    }
    let (shards, mode) = match name {
        "quadratic" | "rff" => (1, SnapMode::Plain),
        "quadratic-sharded" | "rff-sharded" => (4, SnapMode::Plain),
        // batch-shared two-pass pool over the single-shard publish point
        // (crate::sampler::kernel::two_pass): same one-tree contract, the
        // adapter just routes draws through the shared-pool engine
        "quadratic-2pass" | "rff-2pass" => (1, SnapMode::TwoPass),
        // inverted multi-index over the single-shard publish point
        // (crate::sampler::kernel::midx): same one-tree contract; the
        // adapter rebuilds its k-means coarse index behind each published
        // generation (warm-restarted — that rebuild is the re-assignment
        // sweep)
        "quadratic-midx" | "rff-midx" => (1, SnapMode::Midx),
        // the streaming samplers own their vocabulary (memtable +
        // tombstones + compactor) and must receive churn-aware
        // update_many through the legacy mutable path at pipeline depth 1
        // — a fixed-shard snapshot split cannot represent a class set
        // that changes between steps
        "quadratic-streaming" | "rff-streaming" => return None,
        _ => return None,
    };
    fn parts<M: FeatureMap + Clone + 'static>(
        map: M,
        n: usize,
        shards: usize,
        w: &[f32],
        mode: SnapMode,
        pool_factor: f64,
    ) -> (Arc<dyn Sampler>, SharedPublisher, Option<TwoPassObs>, Option<MidxObs>) {
        let set = ShardSet::new(map, n, shards, None, Some(w));
        let base = set.snapshot_sampler();
        let (sampler, pool_obs, midx_obs): (Arc<dyn Sampler>, _, _) = match mode {
            SnapMode::TwoPass => {
                let s = base.with_two_pass(pool_factor);
                let obs = s.two_pass_obs().cloned();
                (Arc::new(s), obs, None)
            }
            SnapMode::Midx => {
                let s = base.with_midx(None);
                let obs = s.midx_obs().cloned();
                (Arc::new(s), None, obs)
            }
            SnapMode::Plain => (Arc::new(base), None, None),
        };
        (sampler, Arc::new(Mutex::new(Box::new(set))), pool_obs, midx_obs)
    }
    Some(if name.starts_with("quadratic") {
        parts(QuadraticMap::new(spec.d, spec.alpha as f64), spec.n_classes, shards, w, mode, pool_factor)
    } else {
        let map = PositiveRffMap::new(RffConfig::new(spec.d, rff::RFF_BUILD_SEED));
        parts(map, spec.n_classes, shards, w, mode, pool_factor)
    })
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Result<Trainer<'e>> {
        let spec = engine.manifest().model(&cfg.model)?.clone();
        let cfg = cfg.with_model_defaults(&spec);
        let dataset: Arc<dyn Dataset> = Arc::from(build_dataset(&spec, &cfg)?);
        let store = ParamStore::init(&spec.params, splitmix64(&mut (cfg.seed ^ 0x1417)))?;
        let unified = if cfg.sampler != "full" && cfg.unified_tree {
            snapshot_backed_parts(&cfg.sampler, &spec, store.out_w().as_f32()?, cfg.pool_factor)
        } else {
            None
        };
        #[allow(clippy::type_complexity)]
        type SamplerParts = (
            Option<Arc<dyn Sampler>>,
            Option<SharedPublisher>,
            Option<TwoPassObs>,
            Option<MidxObs>,
        );
        let (sampler, publisher, pool_obs, midx_obs): SamplerParts =
            if cfg.sampler == "full" {
                (None, None, None, None)
            } else if let Some((s, p, o, mo)) = unified {
                (Some(s), Some(p), o, mo)
            } else {
                let stats = dataset.stats();
                let boxed = build_sampler(
                    &cfg.sampler,
                    spec.n_classes,
                    spec.d,
                    spec.alpha,
                    spec.abs_logits,
                    Some(&stats),
                    Some(store.out_w().as_f32()?),
                )?;
                (Some(Arc::from(boxed)), None, None, None)
            };
        let threads = if cfg.threads == 0 { default_threads() } else { cfg.threads };
        let rng = Rng::new(cfg.seed ^ 0x7141_1e5);
        // Overlap needs a sampler whose state cannot change under a
        // background draw: snapshot-backed (pinned generations) or one the
        // trainer never updates (no h dependence). Legacy mutable samplers
        // (the flat w-mirror oracles) run sequentially.
        // one registry across the run: phase cells register as they are
        // touched, and the publisher binds its publish-path + sampler
        // cells up front, so `phases.registry().snapshot()` is the whole
        // trainer-side telemetry surface (logged as kind:"telemetry")
        let phases = PhaseTimes::default();
        if let Some(p) = &publisher {
            p.lock().expect("publisher poisoned").register_metrics(phases.registry());
        }
        if let Some(obs) = &pool_obs {
            // two-pass engines carry their own kss_sampler_pool_* cells
            obs.register_into(phases.registry());
        }
        if let Some(obs) = &midx_obs {
            // midx engines carry their own kss_sampler_midx_* cells
            obs.register_into(phases.registry());
        }
        let overlap_safe = sampler.as_ref().is_some_and(|s| s.snapshot_backed() || !s.needs().h);
        let depth = if cfg.pipeline_depth > 1 && !overlap_safe {
            if sampler.is_some() {
                crate::info!(
                    "pipeline depth {} downgraded to 1: sampler '{}' mutates per-step state",
                    cfg.pipeline_depth,
                    cfg.sampler
                );
            }
            // full softmax has no sampling stage to overlap: clamp silently
            1
        } else {
            cfg.pipeline_depth.clamp(1, 2)
        };
        Ok(Trainer {
            engine,
            spec,
            cfg,
            store,
            sampler,
            dataset,
            rng,
            phases,
            threads,
            step_count: 0,
            publisher,
            ops: OpCache::default(),
            scratch: StepScratch::default(),
            driver: PipelineDriver::new(depth),
        })
    }

    /// Attach online serving over the quadratic kernel: with the unified
    /// tree this hands back the publish points the trainer *already*
    /// maintains; otherwise it builds the serving mirror (which then is
    /// the only kernel tree in the system). Returns the per-shard publish
    /// points and shard offsets — exactly what
    /// [`crate::serve::SamplingService::start`] takes — so online readers
    /// sample the training-fresh distribution while the trainer keeps
    /// stepping.
    #[allow(clippy::type_complexity)]
    pub fn enable_serving(
        &mut self,
        shards: usize,
    ) -> Result<(Vec<Arc<SnapshotStore<TreeSnapshot<QuadraticMap>>>>, Vec<u32>)> {
        let map = QuadraticMap::new(self.spec.d, self.spec.alpha as f64);
        self.enable_serving_with(map, shards)
    }

    /// [`Trainer::enable_serving`] over any kernel family. When the
    /// trainer's sampler is already snapshot-backed, the existing
    /// [`ShardSet`] is reused — one tree, one update sweep, one publish
    /// point shared by training and serving; the `shards` argument is
    /// advisory then (topology is pinned by the sampler kind for
    /// bit-reproducibility), and a kernel-family mismatch is an error.
    #[allow(clippy::type_complexity)]
    pub fn enable_serving_with<M: FeatureMap + Clone + 'static>(
        &mut self,
        map: M,
        shards: usize,
    ) -> Result<(Vec<Arc<SnapshotStore<TreeSnapshot<M>>>>, Vec<u32>)> {
        if let Some(publisher) = &self.publisher {
            let guard = publisher.lock().expect("publisher poisoned");
            let set = guard.as_any().downcast_ref::<ShardSet<M>>().ok_or_else(|| {
                anyhow::anyhow!(
                    "serving kernel family does not match the training sampler '{}'",
                    self.cfg.sampler
                )
            })?;
            if shards != set.shard_count() {
                crate::info!(
                    "serving shard count {} ignored: topology pinned by sampler '{}' ({} shard(s))",
                    shards,
                    self.cfg.sampler,
                    set.shard_count()
                );
            }
            return Ok((set.stores(), set.offsets().to_vec()));
        }
        let set = ShardSet::new(
            map,
            self.spec.n_classes,
            shards,
            None,
            Some(self.store.out_w().as_f32()?),
        );
        let stores = set.stores();
        let offsets = set.offsets().to_vec();
        // late-built serving mirror: bind its cells into the run registry
        // like the construction-time publisher would have been
        set.register_metrics(self.phases.registry());
        self.publisher = Some(Arc::new(Mutex::new(Box::new(set))));
        Ok((stores, offsets))
    }

    /// Aggregated publish counters (None when no publisher exists — i.e. a
    /// non-tree sampler with serving never enabled). Complete once
    /// [`Trainer::train`] returns; mid-run, depth-2 publishes may still be
    /// in flight on the pipeline worker.
    pub fn publish_stats(&self) -> Option<crate::serve::PublishStats> {
        self.publisher
            .as_ref()
            .map(|p| p.lock().expect("publisher poisoned").publish_stats())
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &dyn Dataset {
        self.dataset.as_ref()
    }

    pub fn steps_taken(&self) -> usize {
        self.step_count
    }

    /// Effective pipeline depth (after the mutable-sampler downgrade).
    pub fn pipeline_depth(&self) -> usize {
        self.driver.depth()
    }

    /// Mean full-softmax CE on held-out data (capped at cfg.eval_batches).
    pub fn eval(&mut self) -> Result<f64> {
        let mut sw = Stopwatch::new();
        OpCache::ensure(&mut self.ops.eval_full, &self.spec, "eval_full")?;
        let mut total = 0.0f64;
        let mut count = 0usize;
        let batches = self.dataset.eval_batches();
        let cap = if self.cfg.eval_batches == 0 { batches.len() } else { self.cfg.eval_batches };
        anyhow::ensure!(!batches.is_empty(), "no eval batches (valid_size too small)");
        {
            let op = self.ops.eval_full.as_ref().expect("ensured above");
            for batch in batches.iter().take(cap) {
                let args = self.args_with(&batch.data, &[]);
                let out = self.engine.execute(op, self.store.len(), &args)?;
                total += out[0].scalar()? as f64;
                count += batch.n_examples();
            }
        }
        self.phases.add("eval", sw.lap());
        Ok(total / count as f64)
    }

    /// One sampled-softmax (or full-softmax) training step, stages run
    /// sequentially on this thread (the depth-1 path; [`Trainer::train`]
    /// switches to the overlapped schedule at depth 2).
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let loss = if self.sampler.is_none() {
            self.step_full(batch)?
        } else {
            let outcome = {
                let task = self.prepare_sample_task(batch, self.step_count)?;
                let sampler = self.sampler.as_ref().expect("sampled step without sampler");
                run_sample_task(sampler.as_ref(), task)
            };
            self.phases.add("sample", outcome.sample_s);
            self.finish_sampled_step(batch, outcome, false)?
        };
        self.step_count += 1;
        Ok(loss)
    }

    /// The depth-2 schedule: collect this step's (already in-flight)
    /// draws, put the *next* step's encode + sampling in flight, then run
    /// this step's device execute/apply/publish while they proceed.
    fn step_overlapped(&mut self, batch: &Batch, next: Option<&Batch>) -> Result<f32> {
        if self.driver.in_flight() == 0 {
            // pipeline head (first step of an epoch): prime it
            let task = self.prepare_sample_task(batch, self.step_count)?;
            let sampler = self.sampler.as_ref().expect("sampled step").clone();
            self.driver.schedule_sample(&sampler, task);
        }
        let (outcome, wait_s) = self.driver.collect_sample();
        self.phases.add("sample_wait", wait_s);
        // only the part of the fan-out that finished before collect was
        // truly hidden; the waited remainder is already on the critical
        // book above
        self.phases.add_overlapped("sample", (outcome.sample_s - wait_s).max(0.0));
        if let Some(next_batch) = next {
            // scheduled before the device step, so the draws overlap it;
            // h is encoded from the pre-step params and q read from the
            // pre-publish generation — the documented one-step staleness,
            // corrected exactly by eq. (2) at that q
            let task = self.prepare_sample_task(next_batch, self.step_count + 1)?;
            let sampler = self.sampler.as_ref().expect("sampled step").clone();
            self.driver.schedule_sample(&sampler, task);
        }
        let loss = self.finish_sampled_step(batch, outcome, true)?;
        self.step_count += 1;
        Ok(loss)
    }

    fn step_full(&mut self, batch: &Batch) -> Result<f32> {
        let mut sw = Stopwatch::new();
        OpCache::ensure(&mut self.ops.train_full, &self.spec, "train_full")?;
        let lr = Tensor::scalar_f32(self.cfg.lr);
        let n_p = self.store.len();
        let out = {
            let op = self.ops.train_full.as_ref().expect("ensured above");
            let args = self.args_with(&batch.data, &[&lr]);
            self.engine.execute(op, n_p, &args)?
        };
        self.store.set_all(&out[..n_p])?;
        self.phases.add("step", sw.lap());
        out[n_p].scalar()
    }

    /// Stage 1 of a sampled step: run the model-dependent artifacts
    /// (encode / score_all) and pack everything the sampling fan-out needs
    /// into an owned [`SampleTask`]. Draws the step seed from the trainer
    /// RNG — always in step order, whatever the pipeline depth.
    fn prepare_sample_task(&mut self, batch: &Batch, step: usize) -> Result<SampleTask> {
        let needs = self.sampler.as_ref().expect("sampled step without sampler").needs();
        let n = batch.n_examples();
        let mut sw = Stopwatch::new();
        let h = if needs.h {
            OpCache::ensure(&mut self.ops.encode, &self.spec, "encode")?;
            let op = self.ops.encode.as_ref().expect("ensured above");
            let data = &batch.data[..op.inputs.len()];
            let args = self.args_with(data, &[]);
            let out = self.engine.execute(op, self.store.len(), &args)?;
            Some(out.into_iter().next().expect("encode returns h").into_f32()?)
        } else {
            None
        };
        let logits = if needs.logits {
            OpCache::ensure(&mut self.ops.score_all, &self.spec, "score_all")?;
            let op = self.ops.score_all.as_ref().expect("ensured above");
            let data = &batch.data[..op.inputs.len()];
            let args = self.args_with(data, &[]);
            let out = self.engine.execute(op, self.store.len(), &args)?;
            Some(out.into_iter().next().expect("score_all returns logits").into_f32()?)
        } else {
            None
        };
        self.phases.add("encode", sw.lap());
        let seed = self.rng.next_u64();
        let rows = self.scratch.take_rows(n, self.cfg.m);
        Ok(SampleTask {
            step,
            seed,
            n,
            d: self.spec.d,
            n_classes: self.spec.n_classes,
            m: self.cfg.m,
            threads: self.threads,
            h,
            logits,
            prev: batch.prev.clone(),
            rows,
        })
    }

    /// Stages 3–5 of a sampled step: assemble the device inputs from the
    /// draws, run the fused sampled-softmax artifact, patch the host
    /// mirror, and run the **single** kernel-tree update sweep (through
    /// the publisher when one exists). `offload_publish` moves that sweep
    /// onto the pipeline worker — only the depth-2 train loop may set it
    /// (its FIFO schedule is what keeps offloaded publishes deterministic
    /// relative to the draws).
    fn finish_sampled_step(
        &mut self,
        batch: &Batch,
        outcome: SampleOutcome,
        offload_publish: bool,
    ) -> Result<f32> {
        let SampleOutcome { rows, result, .. } = outcome;
        result?;
        let n = batch.n_examples();
        let m = self.cfg.m;
        let s_dim = m + 1;
        let d = self.spec.d;
        let mut sw = Stopwatch::new();

        // assemble neg (N, m), sub (N, m+1) and s (N, S) into the pooled
        // step scratch (allocation-free in steady state)
        self.scratch.neg.clear();
        self.scratch.sub.clear();
        self.scratch.s_idx.clear();
        self.scratch.neg.reserve(n * m);
        self.scratch.sub.reserve(n * s_dim);
        self.scratch.s_idx.reserve(n * s_dim);
        for (i, row) in rows.iter().enumerate() {
            debug_assert_eq!(row.classes.len(), m);
            self.scratch.sub.push(0.0f32); // positive: uncorrected (eq. 2)
            self.scratch.s_idx.push(batch.pos[i]);
            for (&c, &q) in row.classes.iter().zip(&row.q) {
                // the sampler layer guarantees q > 0 (see sampler/mod.rs);
                // a violation here would send ln(m·q) = -inf on-device
                debug_assert!(q > 0.0 && q.is_finite(), "sampler reported q = {q}");
                self.scratch.neg.push(c as i32);
                self.scratch.sub.push(((m as f64) * q).ln() as f32);
                self.scratch.s_idx.push(c as i32);
            }
        }

        // fused sampled-softmax step on-device
        self.ops.ensure_train_sampled(&self.spec, m)?;
        let neg_t = Tensor::i32s(&[n, m], std::mem::take(&mut self.scratch.neg));
        let sub_t = Tensor::f32s(&[n, s_dim], std::mem::take(&mut self.scratch.sub));
        let lr = Tensor::scalar_f32(self.cfg.lr);
        let n_p = self.store.len();
        let out = {
            let op = &self.ops.train_sampled.as_ref().expect("ensured above").1;
            let args = self.args_with(&batch.data, &[&neg_t, &sub_t, &lr]);
            self.engine.execute(op, n_p, &args)?
        };
        self.store.set_all(&out[..n_p])?;
        let loss = out[n_p].scalar()?;
        // staging buffers give their allocations back to the scratch
        self.scratch.neg = neg_t.into_i32().expect("staged as i32");
        self.scratch.sub = sub_t.into_f32().expect("staged as f32");
        self.phases.add("step", sw.lap());

        // host mirror + the single Fig. 1(b) tree sweep
        let changed = self
            .store
            .apply_sampled_rows(&self.scratch.s_idx, &out[n_p + 1])
            .context("applying updated rows")?;
        let (needs_h, snapshot_backed, owns_tree) = {
            let s = self.sampler.as_ref().expect("sampled step");
            (s.needs().h, s.snapshot_backed(), s.owns_kernel_tree())
        };
        let mut tree_sweeps = 0u32;
        if (needs_h && !snapshot_backed) || self.publisher.is_some() {
            // flat copy of the changed rows (sorted + deduped by
            // apply_sampled_rows), shared by every consumer below; the
            // buffer round-trips through the driver's publish pool
            let mut rows_flat = self.driver.take_rows_buf();
            rows_flat.clear();
            rows_flat.reserve(changed.len() * d);
            for &class in &changed {
                rows_flat.extend_from_slice(self.store.out_row(class));
            }
            if needs_h && !snapshot_backed {
                // legacy samplers that mirror state (flat oracles, or the
                // private-tree reference path): update in place. The Arc
                // is unique here — mutable samplers force depth 1.
                let s = self.sampler.as_mut().expect("sampled step");
                Arc::get_mut(s)
                    .expect("sampler aliased during update (depth must be 1)")
                    .update_many(&changed, &rows_flat);
                if owns_tree {
                    tree_sweeps += 1;
                }
            }
            self.phases.add("update", sw.lap());
            if let Some(publisher) = &self.publisher {
                // the one tree-update sweep + publish; offloaded behind
                // the in-flight sampling at depth 2's train loop (the
                // publish lands before the next-but-one step's draws —
                // FIFO). Inline steps publish on this thread so draws
                // stay deterministic outside the overlapped schedule.
                tree_sweeps += 1;
                if let Some(secs) =
                    self.driver.schedule_publish(publisher, changed, rows_flat, offload_publish)
                {
                    self.phases.add("publish", secs);
                }
            } else {
                self.driver.put_rows_buf(rows_flat);
            }
        } else {
            self.phases.add("update", sw.lap());
        }
        // the refactor's invariant: never two kernel-tree sweeps per step,
        // and the snapshot-backed path always has exactly its publisher
        // one. (The test-only unified_tree=false reference deliberately
        // reproduces the pre-pipeline duplicated behavior when combined
        // with serving, so it is exempt.)
        debug_assert!(
            tree_sweeps <= 1 || !self.cfg.unified_tree,
            "duplicated kernel-tree update sweep ({tree_sweeps})"
        );
        debug_assert!(
            !snapshot_backed || tree_sweeps == 1,
            "snapshot-backed sampler without its publisher sweep"
        );
        self.scratch.put_rows(rows);
        Ok(loss)
    }

    /// params + data (+ extras) in artifact order.
    fn args_with<'a>(&'a self, data: &'a [Tensor], extra: &[&'a Tensor]) -> Vec<&'a Tensor> {
        let mut args: Vec<&Tensor> = self.store.values().iter().collect();
        args.extend(data.iter());
        args.extend(extra.iter().copied());
        args
    }

    /// Run the full schedule, logging eval points to the sink.
    pub fn train(&mut self, metrics: &mut MetricsSink) -> Result<TrainResult> {
        metrics.log_config(&self.cfg.to_json());
        let initial = self.eval()?;
        metrics.log_eval(EvalPoint { epoch: 0.0, step: 0, loss: initial });

        // epoch batches generate one epoch ahead on a background thread;
        // the `prefetch` phase records only the wait that remained visible
        let mut prefetch = BatchPrefetcher::start(
            self.dataset.clone(),
            self.cfg.epochs,
            self.cfg.max_steps_per_epoch,
        );
        let overlapped = self.driver.overlapped() && self.sampler.is_some();
        let mut last_train_loss = f32::NAN;
        for epoch in 0..self.cfg.epochs {
            let (got_epoch, batches, wait_s) =
                prefetch.next_epoch().ok_or_else(|| anyhow::anyhow!("prefetcher ended early"))?;
            debug_assert_eq!(got_epoch, epoch);
            self.phases.add("prefetch", wait_s);
            anyhow::ensure!(!batches.is_empty(), "no train batches (train_size too small)");
            let steps_per_epoch = batches.len();
            let mut train_loss_sum = 0.0f64;
            for (bi, batch) in batches.iter().enumerate() {
                let loss = if overlapped {
                    self.step_overlapped(batch, batches.get(bi + 1))?
                } else {
                    self.step(batch)?
                };
                train_loss_sum += loss as f64;
                let step = epoch * steps_per_epoch + bi + 1;
                if self.cfg.eval_every > 0 && step % self.cfg.eval_every == 0 {
                    let loss = self.eval()?;
                    let epoch_f = step as f64 / steps_per_epoch as f64;
                    metrics.log_eval(EvalPoint { epoch: epoch_f, step, loss });
                }
            }
            last_train_loss = (train_loss_sum / steps_per_epoch as f64) as f32;
            let loss = self.eval()?;
            let step = (epoch + 1) * steps_per_epoch;
            metrics.log_eval(EvalPoint { epoch: (epoch + 1) as f64, step, loss });
            // periodic telemetry snapshot (phase cells + publish path +
            // sampler monitors), interleaved with the eval stream so the
            // two can be joined on `step`
            metrics.log_record(
                "telemetry",
                vec![
                    ("step", crate::util::json::Value::num(step as f64)),
                    ("metrics", self.phases.registry().snapshot().to_value()),
                ],
            );
            crate::info!(
                "[{}] epoch {}/{} eval_loss {:.4} (train {:.4})",
                metrics.run_id(),
                epoch + 1,
                self.cfg.epochs,
                loss,
                last_train_loss
            );
        }
        // pipeline epilogue: land every offloaded publish and book the
        // wall time it hid behind the device steps
        let hidden_publish_s = self.driver.drain();
        if hidden_publish_s > 0.0 {
            self.phases.add_overlapped("publish", hidden_publish_s);
        }
        // per-phase wall accounting + steps/sec into the metrics JSONL, so
        // pipeline wins are visible outside the benches (kss train prints
        // the same breakdown at the end of the run)
        metrics.log_record("phase_times", vec![("timing", self.phases.to_json(self.step_count))]);
        // final telemetry snapshot, after the drain booked the hidden
        // publish time — the run's closing registry state
        metrics.log_record(
            "telemetry",
            vec![
                ("step", crate::util::json::Value::num(self.step_count as f64)),
                ("metrics", self.phases.registry().snapshot().to_value()),
            ],
        );
        Ok(TrainResult {
            final_loss: metrics.final_loss().unwrap_or(f64::NAN),
            best_loss: metrics.best_loss().unwrap_or(f64::NAN),
            curve: metrics.curve().to_vec(),
            steps: self.step_count,
            last_train_loss: last_train_loss as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn engine() -> Option<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Engine::new(&dir).unwrap())
    }

    fn tiny_cfg(sampler: &str, m: usize) -> TrainConfig {
        TrainConfig {
            model: "tiny".into(),
            sampler: sampler.into(),
            m,
            lr: 0.3,
            epochs: 1,
            train_size: 640,
            valid_size: 160,
            eval_batches: 5,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn full_softmax_baseline_learns() {
        let Some(engine) = engine() else { return };
        let mut t = Trainer::new(&engine, tiny_cfg("full", 0)).unwrap();
        let mut sink = MetricsSink::memory("t");
        let res = t.train(&mut sink).unwrap();
        assert!(res.steps > 10);
        assert!(
            res.final_loss < res.curve[0].loss - 0.1,
            "full softmax must reduce eval loss: {:?}",
            res.curve
        );
    }

    #[test]
    fn sampled_training_sampler_quality_ordering() {
        // The paper's core claim at tiny scale: adaptive samplers (softmax =
        // unbiased oracle, quadratic kernel) learn; uniform at small m
        // (8 of 128 classes) is visibly biased and ends up worse.
        let Some(engine) = engine() else { return };
        let mut finals = std::collections::BTreeMap::new();
        for sampler in ["uniform", "unigram", "softmax", "quadratic", "quadratic-flat", "quartic"] {
            let mut t = Trainer::new(&engine, tiny_cfg(sampler, 8)).unwrap();
            let mut sink = MetricsSink::memory(sampler);
            let res = t.train(&mut sink).unwrap();
            finals.insert(sampler, (res.curve[0].loss, res.final_loss));
        }
        for sampler in ["softmax", "quadratic", "quadratic-flat", "quartic"] {
            let (initial, fin) = finals[sampler];
            assert!(fin < initial - 0.05, "{sampler} failed to learn: {initial} -> {fin}");
        }
        // bias ordering (Figure 2's shape): model-adaptive < static
        assert!(finals["softmax"].1 < finals["uniform"].1, "{finals:?}");
        assert!(finals["quadratic"].1 < finals["uniform"].1, "{finals:?}");
        // the tree sampler and its flat oracle must land close together
        let diff = (finals["quadratic"].1 - finals["quadratic-flat"].1).abs();
        assert!(diff < 0.25, "tree vs flat quadratic diverged: {finals:?}");
    }

    #[test]
    fn bigram_on_lm_dataset_learns() {
        let Some(engine) = engine() else { return };
        let cfg = TrainConfig {
            model: "tiny-lm".into(),
            sampler: "bigram".into(),
            m: 4,
            lr: 0.5,
            epochs: 1,
            train_size: 3_000,
            valid_size: 600,
            eval_batches: 4,
            max_steps_per_epoch: 60,
            ..Default::default()
        };
        let mut t = Trainer::new(&engine, cfg).unwrap();
        let mut sink = MetricsSink::memory("bigram-lm");
        let res = t.train(&mut sink).unwrap();
        assert!(res.final_loss < res.curve[0].loss, "{:?}", res.curve);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(engine) = engine() else { return };
        let run = |seed: u64| {
            let mut cfg = tiny_cfg("quadratic", 4);
            cfg.seed = seed;
            cfg.epochs = 1;
            cfg.max_steps_per_epoch = 10;
            let mut t = Trainer::new(&engine, cfg).unwrap();
            let mut sink = MetricsSink::memory("det");
            t.train(&mut sink).unwrap().final_loss
        };
        let a = run(9);
        let b = run(9);
        let c = run(10);
        assert_eq!(a, b, "same seed must reproduce exactly");
        assert_ne!(a, c, "different seed should differ");
    }

    #[test]
    fn unified_tree_matches_private_tree_bitwise() {
        // THE depth-1 acceptance pin: routing the quadratic sampler through
        // the serve snapshot layer (one shared tree, publisher sweep) must
        // reproduce the legacy private-tree sequential loop bit for bit —
        // same seed ⇒ identical eval curve and identical final parameters.
        let Some(engine) = engine() else { return };
        let run = |unified: bool| {
            let mut cfg = tiny_cfg("quadratic", 4);
            cfg.unified_tree = unified;
            cfg.max_steps_per_epoch = 12;
            let mut t = Trainer::new(&engine, cfg).unwrap();
            let mut sink = MetricsSink::memory(if unified { "uni" } else { "ref" });
            let res = t.train(&mut sink).unwrap();
            let params: Vec<Vec<f32>> =
                t.store.values().iter().map(|v| v.as_f32().unwrap().to_vec()).collect();
            (res.curve, params)
        };
        let (curve_a, params_a) = run(true);
        let (curve_b, params_b) = run(false);
        assert_eq!(curve_a, curve_b, "eval curves diverged");
        assert_eq!(params_a, params_b, "final params diverged");
    }

    #[test]
    fn depth2_is_deterministic_and_still_beats_uniform() {
        // depth-2 overlap: same seed ⇒ identical run (any thread count);
        // and the one-step-stale quadratic proposal still beats uniform on
        // the tiny ordering task (the staleness regression)
        let Some(engine) = engine() else { return };
        let run = |sampler: &str, depth: usize, threads: usize| {
            let mut cfg = tiny_cfg(sampler, 8);
            cfg.pipeline_depth = depth;
            cfg.threads = threads;
            let mut t = Trainer::new(&engine, cfg).unwrap();
            let mut sink = MetricsSink::memory("p2");
            let res = t.train(&mut sink).unwrap();
            let w = t.store.out_w().as_f32().unwrap().to_vec();
            (res.final_loss, res.curve, w)
        };
        let (a_loss, a_curve, a_w) = run("quadratic", 2, 2);
        let (b_loss, b_curve, b_w) = run("quadratic", 2, 4);
        assert_eq!(a_loss, b_loss, "depth-2 must not depend on thread count");
        assert_eq!(a_curve, b_curve);
        assert_eq!(a_w, b_w);
        let (d1_loss, ..) = run("quadratic", 1, 2);
        let (uni_loss, ..) = run("uniform", 2, 2);
        assert!(a_loss < uni_loss, "stale quadratic {a_loss} should beat uniform {uni_loss}");
        // depth-2 is a different (stale-q) trajectory, not a broken one
        assert!((a_loss - d1_loss).abs() < 0.5, "depth-2 diverged wildly: {a_loss} vs {d1_loss}");
    }

    #[test]
    fn two_pass_sampler_learns_and_reports_pool_telemetry() {
        // the batch-shared two-pass mode through the full unified-tree
        // trainer: snapshot-backed (so depth-2 overlap is allowed), still
        // learns on the tiny task, and its kss_sampler_pool_* cells land
        // in the run registry
        let Some(engine) = engine() else { return };
        let mut cfg = tiny_cfg("quadratic-2pass", 8);
        cfg.pipeline_depth = 2;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        assert_eq!(t.pipeline_depth(), 2, "two-pass is snapshot-backed: overlap must be allowed");
        let mut sink = MetricsSink::memory("2pass");
        let res = t.train(&mut sink).unwrap();
        assert!(
            res.final_loss < res.curve[0].loss - 0.05,
            "two-pass failed to learn: {:?}",
            res.curve
        );
        let snap = t.phases.registry().snapshot();
        let hits = snap.counter("kss_sampler_pool_hit_total").unwrap_or(0);
        let misses = snap.counter("kss_sampler_pool_miss_total").unwrap_or(0);
        assert!(hits + misses > 0, "pool counters never moved");
        assert!(snap.gauge("kss_sampler_pool_size").unwrap_or(0.0) >= 8.0);
        assert!(
            snap.hist("kss_sampler_pool_rescore_seconds").map(|h| h.count()).unwrap_or(0) > 0,
            "rescore latency histogram never recorded"
        );
    }

    #[test]
    fn midx_sampler_learns_and_reports_index_telemetry() {
        // the inverted-multi-index mode through the full unified-tree
        // trainer: snapshot-backed (so depth-2 overlap is allowed), still
        // learns on the tiny task, and its kss_sampler_midx_* cells land
        // in the run registry
        let Some(engine) = engine() else { return };
        let mut cfg = tiny_cfg("quadratic-midx", 8);
        cfg.pipeline_depth = 2;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        assert_eq!(t.pipeline_depth(), 2, "midx is snapshot-backed: overlap must be allowed");
        let mut sink = MetricsSink::memory("midx");
        let res = t.train(&mut sink).unwrap();
        assert!(
            res.final_loss < res.curve[0].loss - 0.05,
            "midx failed to learn: {:?}",
            res.curve
        );
        let snap = t.phases.registry().snapshot();
        let coarse = snap.counter("kss_sampler_midx_coarse_draw_total").unwrap_or(0);
        assert!(coarse > 0, "coarse-draw counter never moved");
        assert!(
            snap.counter("kss_sampler_midx_refine_total").unwrap_or(0) > 0,
            "refine counter never moved"
        );
        assert!(
            snap.counter("kss_sampler_midx_reassign_total").unwrap_or(0) > 0,
            "no warm index rebuild despite per-step publishes"
        );
        assert!(snap.gauge("kss_sampler_midx_clusters").unwrap_or(0.0) >= 1.0);
    }

    #[test]
    fn serving_publisher_tracks_training() {
        // ONE tree: enable_serving on a snapshot-backed trainer returns the
        // publish points the sampler already reads (1 store for the
        // unsharded quadratic kind); snapshots advance one generation per
        // sampled step and mirror the trained table exactly
        let Some(engine) = engine() else { return };
        let mut cfg = tiny_cfg("quadratic", 4);
        cfg.max_steps_per_epoch = 6;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        assert!(t.publish_stats().is_some(), "unified tree publishes from step 0");
        let (stores, offsets) = t.enable_serving(2).unwrap();
        assert_eq!(stores.len(), 1, "unsharded quadratic pins a 1-shard topology");
        assert!(stores.iter().all(|s| s.generation() == 0));
        let mut sink = MetricsSink::memory("serve-hook");
        t.train(&mut sink).unwrap();
        let stats = t.publish_stats().unwrap();
        assert_eq!(stats.publishes as usize, {
            // every step publishes each shard it touched
            let total: u64 = stores.iter().map(|s| s.generation()).sum();
            total as usize
        });
        assert!(stats.publishes >= 6, "no publishes happened: {stats:?}");
        // the run registry unifies all trainer-side telemetry: phase cells,
        // the publish path, and the sampler internals behind the snapshots
        let snap = t.phases.registry().snapshot();
        let lag = snap.hist("kss_publish_lag_seconds").expect("publish lag not registered");
        assert_eq!(lag.count(), stats.publishes, "publish lag count != publishes");
        assert!(
            snap.counter("kss_sampler_draws_total").unwrap_or(0) > 0,
            "tree draws invisible to the run registry"
        );
        assert!(
            snap.hist("kss_phase_sample_seconds").is_some(),
            "phase cells missing from the run registry"
        );
        // published snapshots mirror the trained table: q over the serve
        // snapshots must match the closed form over the live weights
        let w = t.store.out_w().as_f32().unwrap().to_vec();
        let spec = t.spec().clone();
        let h: Vec<f32> = (0..spec.d).map(|i| (i as f32 * 0.37).sin()).collect();
        let snaps: Vec<_> = stores.iter().map(|s| s.load().1).collect();
        let phi = snaps[0].tree.phi_query(&h);
        let total: f64 = snaps.iter().map(|s| s.tree.partition(&phi).max(0.0)).sum();
        let map = crate::sampler::QuadraticMap::new(spec.d, spec.alpha as f64);
        use crate::sampler::kernel::FeatureMap;
        for class in [0usize, spec.n_classes / 2, spec.n_classes - 1] {
            let sid = crate::serve::shard::shard_of_class(&offsets, class);
            let local = class - offsets[sid] as usize;
            let got = snaps[sid].tree.feature_map().kernel(&h, snaps[sid].tree.emb_row(local))
                / total;
            let want = map.kernel(&h, &w[class * spec.d..(class + 1) * spec.d])
                / (0..spec.n_classes)
                    .map(|j| map.kernel(&h, &w[j * spec.d..(j + 1) * spec.d]))
                    .sum::<f64>();
            assert!((got - want).abs() < 1e-6, "class {class}: {got} vs {want}");
        }
        // a second kernel family cannot attach to the quadratic publisher
        let err = t
            .enable_serving_with(
                crate::sampler::PositiveRffMap::new(crate::sampler::RffConfig::new(
                    spec.d,
                    crate::sampler::rff::RFF_BUILD_SEED,
                )),
                2,
            )
            .unwrap_err();
        assert!(err.to_string().contains("kernel family"), "{err}");
    }

    #[test]
    fn m_must_have_artifact() {
        let Some(engine) = engine() else { return };
        let mut cfg = tiny_cfg("uniform", 5); // no m=5 artifact for tiny
        cfg.max_steps_per_epoch = 1;
        let mut t = Trainer::new(&engine, cfg).unwrap();
        let mut sink = MetricsSink::memory("bad-m");
        let err = t.train(&mut sink).unwrap_err();
        assert!(err.to_string().contains("m=5"), "{err}");
    }
}
