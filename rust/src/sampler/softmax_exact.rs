//! Exact softmax sampling, `q_i ∝ exp(o_i)` — the unique unbiased sampling
//! distribution (Theorem 2.1), and exactly as expensive as computing the
//! full softmax: it needs every logit. The trainer obtains the logits from
//! the `score_all` artifact (one device matmul per batch); this sampler then
//! builds the per-example CDF in O(n) and draws its m negatives by binary
//! search.
//!
//! For absolute-softmax models (§3.3) the unbiased distribution is
//! `q_i ∝ exp(|o_i|)` (the theorem applies to the modified output |o|).

use super::{Needs, Sample, SampleInput, Sampler};
use crate::util::rng::{Cdf, Rng};
use anyhow::Result;

/// The Theorem-2.1 oracle sampler.
pub struct SoftmaxSampler {
    n: usize,
    abs_logits: bool,
}

impl SoftmaxSampler {
    pub fn new(n: usize, abs_logits: bool) -> SoftmaxSampler {
        SoftmaxSampler { n, abs_logits }
    }

    /// exp-normalized weights with max-subtraction for stability.
    fn weights(&self, logits: &[f32]) -> Vec<f32> {
        if self.abs_logits {
            let max = logits.iter().map(|&o| o.abs()).fold(f32::NEG_INFINITY, f32::max);
            logits.iter().map(|&o| (o.abs() - max).exp()).collect()
        } else {
            // shared ops-layer row max (exact: the max is an input value)
            let max = crate::ops::row_max(logits) as f32;
            logits.iter().map(|&o| (o - max).exp()).collect()
        }
    }
}

impl Sampler for SoftmaxSampler {
    fn name(&self) -> &str {
        "softmax"
    }

    fn needs(&self) -> Needs {
        Needs { logits: true, ..Needs::default() }
    }

    fn sample(&self, input: &SampleInput, m: usize, rng: &mut Rng, out: &mut Sample) -> Result<()> {
        let logits =
            input.logits.ok_or_else(|| anyhow::anyhow!("softmax sampler needs logits"))?;
        anyhow::ensure!(logits.len() == self.n, "logits len {} != n {}", logits.len(), self.n);
        out.clear();
        let w = self.weights(logits);
        let cdf = Cdf::new(&w).ok_or_else(|| anyhow::anyhow!("degenerate softmax weights"))?;
        for _ in 0..m {
            let c = cdf.sample(rng);
            // Cdf::sample only returns positive-weight indices; the clamp
            // keeps q > 0 even if the ratio to a huge total underflows.
            out.push(c as u32, cdf.prob(c).max(f64::MIN_POSITIVE));
        }
        Ok(())
    }

    fn prob(&self, input: &SampleInput, class: u32) -> Option<f64> {
        let logits = input.logits?;
        let w = self.weights(logits);
        let total: f64 = w.iter().map(|&x| x as f64).sum();
        Some(w[class as usize] as f64 / total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::test_util::empirical_tv;

    fn softmax(o: &[f32], abs: bool) -> Vec<f64> {
        let eff = |x: f32| if abs { x.abs() } else { x };
        let mx = o.iter().map(|&x| eff(x)).fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f64> = o.iter().map(|&x| ((eff(x) - mx) as f64).exp()).collect();
        let z: f64 = e.iter().sum();
        e.into_iter().map(|x| x / z).collect()
    }

    #[test]
    fn q_matches_softmax() {
        let logits = vec![0.0f32, 1.0, -2.0, 3.0, 0.5];
        let s = SoftmaxSampler::new(5, false);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let want = softmax(&logits, false);
        for c in 0..5 {
            assert!((s.prob(&input, c).unwrap() - want[c as usize]).abs() < 1e-6);
        }
        let tv = empirical_tv(&s, &input, &want, 200_000, 5);
        assert!(tv < 0.02, "tv {tv}");
    }

    #[test]
    fn abs_variant_uses_abs_logits() {
        let logits = vec![-3.0f32, 0.0, 3.0];
        let s = SoftmaxSampler::new(3, true);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let q = |c| s.prob(&input, c).unwrap();
        assert!((q(0) - q(2)).abs() < 1e-9, "|o| symmetric: {} vs {}", q(0), q(2));
        assert!(q(0) > q(1));
    }

    #[test]
    fn large_logits_are_stable() {
        let logits = vec![500.0f32, 499.0, -500.0];
        let s = SoftmaxSampler::new(3, false);
        let input = SampleInput { logits: Some(&logits), ..Default::default() };
        let mut rng = Rng::new(1);
        let mut out = Sample::default();
        s.sample(&input, 16, &mut rng, &mut out).unwrap();
        assert!(out.q.iter().all(|q| q.is_finite() && *q > 0.0));
        assert!(out.classes.iter().all(|&c| c < 2), "class 2 has ~0 prob");
    }

    #[test]
    fn missing_logits_is_error() {
        let s = SoftmaxSampler::new(4, false);
        let mut rng = Rng::new(0);
        let mut out = Sample::default();
        assert!(s.sample(&SampleInput::default(), 2, &mut rng, &mut out).is_err());
    }
}
