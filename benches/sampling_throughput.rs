//! §3.2 cost claims — **O(D log n) sampling and updates**.
//!
//! Benchmarks the kernel tree against the exact alternatives across catalog
//! sizes:
//!
//! * draw throughput: tree (O(D log n)) vs flat kernel (O(n d)) vs exact
//!   softmax CDF (O(n)) — the crossover demonstrates why adaptive sampling
//!   is affordable at all;
//! * per-class update cost (root-to-leaf z maintenance, Fig. 1(b));
//! * scaling in n at fixed d: tree time should grow ~log n while flat grows
//!   linearly;
//! * the inverted multi-index (`midx`) engine alongside the tree at every
//!   catalog size — its per-example cost is one O(K) coarse CDF plus
//!   memoized cluster refines, so its throughput profile complements the
//!   bias/MAC frontier in `benches/ablation_tree.rs`.
//!
//! No artifacts needed (pure L3). `cargo bench --bench sampling_throughput`.

use kss::bench_harness::{print_speedup, print_table, scale, write_json, Bencher, BenchRow, Scale};
use kss::sampler::{
    row_rng, BatchSampleInput, FlatKernelSampler, KernelKind, KernelTreeSampler,
    MidxKernelSampler, QuadraticMap, Sample, SampleInput, Sampler, SoftmaxSampler,
};
use kss::util::rng::Rng;
use kss::util::threadpool::default_threads;

fn main() {
    let d = 64usize;
    let m = 32usize;
    let ns: Vec<usize> = match scale() {
        Scale::Quick => vec![1_000, 10_000, 100_000],
        Scale::Full => vec![1_000, 10_000, 100_000, 300_000],
    };
    let bencher = Bencher { warmup_iters: 2, min_iters: 5, max_iters: 200, budget_s: 1.5 };

    let mut draw_rows: Vec<BenchRow> = Vec::new();
    let mut update_rows: Vec<BenchRow> = Vec::new();
    let mut batch_rows: Vec<BenchRow> = Vec::new();
    let mut batch_speedups: Vec<(usize, BenchRow, BenchRow)> = Vec::new();

    for &n in &ns {
        let mut rng = Rng::new(4 + n as u64);
        let mut w = vec![0.0f32; n * d];
        rng.fill_normal(&mut w, 0.3);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // the flat/exact samplers need all n logits per example — that O(n·d)
        // is the adaptivity cost the kernel tree exists to avoid, so it is
        // charged inside their benched closures below.
        let compute_logits = |logits: &mut [f32]| {
            for (j, slot) in logits.iter_mut().enumerate() {
                *slot = w[j * d..(j + 1) * d].iter().zip(&h).map(|(&a, &b)| a * b).sum();
            }
        };

        let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
        tree.reset_embeddings(&w, n, d);
        let flat = FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 });
        let exact = SoftmaxSampler::new(n, false);

        let mut out = Sample::default();
        let input_h = SampleInput { h: Some(&h), ..Default::default() };

        let mut midx = MidxKernelSampler::new(QuadraticMap::new(d, 100.0), n, None);
        Sampler::reset_embeddings(&mut midx, &w, n, d);

        let mut r = Rng::new(1);
        draw_rows.push(bencher.run_with_items(
            &format!("tree    n={n:>6} (m={m} draws/example)"),
            Some(m as f64),
            || tree.sample(&input_h, m, &mut r, &mut out).unwrap(),
        ));
        let mut r = Rng::new(1);
        draw_rows.push(bencher.run_with_items(
            &format!("midx    n={n:>6} (K={} coarse + refine)", midx.clusters()),
            Some(m as f64),
            || midx.sample(&input_h, m, &mut r, &mut out).unwrap(),
        ));
        let mut r = Rng::new(1);
        let mut scratch = vec![0.0f32; n];
        draw_rows.push(bencher.run_with_items(
            &format!("flat    n={n:>6} (incl. O(nd) logits)"),
            Some(m as f64),
            || {
                compute_logits(&mut scratch);
                let inp = SampleInput { logits: Some(&scratch), ..Default::default() };
                flat.sample(&inp, m, &mut r, &mut out).unwrap()
            },
        ));
        let mut r = Rng::new(1);
        let mut scratch = vec![0.0f32; n];
        draw_rows.push(bencher.run_with_items(
            &format!("softmax n={n:>6} (incl. O(nd) logits)"),
            Some(m as f64),
            || {
                compute_logits(&mut scratch);
                let inp = SampleInput { logits: Some(&scratch), ..Default::default() };
                exact.sample(&inp, m, &mut r, &mut out).unwrap()
            },
        ));

        // batched engine vs per-example draws over one training step's
        // batch: same per-row RNG streams, same results — the batched path
        // reuses one arena scratch pool per worker (zero per-example
        // allocation) and owns the thread fan-out.
        let batch_examples = 64usize;
        let threads = default_threads();
        let mut hs = vec![0.0f32; batch_examples * d];
        rng.fill_normal(&mut hs, 1.0);
        let base_input = BatchSampleInput {
            n: batch_examples,
            d,
            n_classes: n,
            h: Some(&hs),
            ..Default::default()
        };
        let mut outs: Vec<Sample> = (0..batch_examples).map(|_| Sample::with_capacity(m)).collect();

        let mut step = 0u64;
        let batched_input = BatchSampleInput { threads, ..base_input };
        let row_batched = bencher.run_with_items(
            &format!("batched   n={n:>6} ({batch_examples} ex × m={m}, {threads} thr)"),
            Some((batch_examples * m) as f64),
            || {
                step += 1;
                tree.sample_batch(&batched_input, m, step, &mut outs).unwrap();
            },
        );
        let mut step = 0u64;
        let row_per_ex = bencher.run_with_items(
            &format!("per-ex    n={n:>6} ({batch_examples} ex × m={m}, 1 thr)"),
            Some((batch_examples * m) as f64),
            || {
                step += 1;
                for (i, slot) in outs.iter_mut().enumerate() {
                    let input = base_input.row(i);
                    let mut r = row_rng(step, i);
                    tree.sample(&input, m, &mut r, slot).unwrap();
                }
            },
        );
        batch_rows.push(row_batched.clone());
        batch_rows.push(row_per_ex.clone());
        batch_speedups.push((n, row_per_ex, row_batched));

        // update cost: one embedding change -> root-to-leaf z refresh
        let mut r = Rng::new(2);
        let mut w_new = vec![0.0f32; d];
        update_rows.push(bencher.run_with_items(
            &format!("tree update n={n:>6} (1 class)"),
            Some(1.0),
            || {
                r.fill_normal(&mut w_new, 0.3);
                let class = r.range(0, n);
                tree.update(class, &w_new);
            },
        ));
        // midx update: two φ evals + one aggregate patch (O(dim), no
        // root-to-leaf path) — the drift-tracked incremental maintenance
        let mut r = Rng::new(2);
        let mut w_new = vec![0.0f32; d];
        update_rows.push(bencher.run_with_items(
            &format!("midx update n={n:>6} (1 class)"),
            Some(1.0),
            || {
                r.fill_normal(&mut w_new, 0.3);
                let class = r.range(0, n);
                midx.update(class, &w_new);
            },
        ));
        println!(
            "tree n={n}: {} nodes, depth {}, leaf_size {} (D = {})",
            tree.node_count(),
            tree.depth(),
            tree.leaf_size(),
            d * d + 1
        );
    }

    // batched-descent series over the feature dimension: the ops-layer
    // surface (fused dot2_32 sibling panels + kernel_many leaf sweeps)
    // scales with D, so this series is where a compute-core win shows up
    // end to end — one fixed batch, D = d²+1 swept via d.
    let mut descent_rows: Vec<BenchRow> = Vec::new();
    {
        let n = 50_000usize;
        let batch_examples = 64usize;
        let threads = default_threads();
        for d in [8usize, 16, 24] {
            let dim = d * d + 1;
            let mut rng = Rng::new(0xD00 + d as u64);
            let mut w = vec![0.0f32; n * d];
            rng.fill_normal(&mut w, 0.3);
            let mut tree = KernelTreeSampler::new(QuadraticMap::new(d, 100.0), n, None);
            tree.reset_embeddings(&w, n, d);
            let mut hs = vec![0.0f32; batch_examples * d];
            rng.fill_normal(&mut hs, 1.0);
            let input = BatchSampleInput {
                n: batch_examples,
                d,
                n_classes: n,
                h: Some(&hs),
                threads,
                ..Default::default()
            };
            let mut outs: Vec<Sample> =
                (0..batch_examples).map(|_| Sample::with_capacity(m)).collect();
            let mut step = 0u64;
            descent_rows.push(bencher.run_with_items(
                &format!("batched descent D={dim:>4} (d={d}, n={n}, {batch_examples} ex × m={m})"),
                Some((batch_examples * m) as f64),
                || {
                    step += 1;
                    tree.sample_batch(&input, m, step, &mut outs).unwrap();
                },
            ));
        }
    }

    print_table("per-example draw cost (m draws incl. φ(h) + memoized node dots)", &draw_rows);
    print_table(
        "batch engine: sample_batch (arena scratch reuse + fan-out) vs per-example loop",
        &batch_rows,
    );
    for (n, per_ex, batched) in &batch_speedups {
        print_speedup(&format!("batched vs per-example @ n={n}"), per_ex, batched);
    }
    println!("(acceptance target: batched ≥ 1.3x the per-example arena baseline at n ≥ 10^4)");
    print_table(
        "batched descent vs feature dim D (ops-layer fused panels; draws/s should track 1/D)",
        &descent_rows,
    );
    print_table("per-class update cost (Fig. 1(b) path refresh)", &update_rows);

    // scaling check: tree grows ~log n (plus touched leaves), exact grows
    // linearly; the crossover sits near n ≈ D·log n — the >= 100k-class
    // regime the paper's YouTube100k experiment lives in.
    // draw_rows groups are [tree, midx, flat, softmax] per catalog size
    let k = ns.len();
    let t_first = draw_rows[0].mean_s;
    let t_last = draw_rows[4 * (k - 1)].mean_s;
    let f_first = draw_rows[2].mean_s;
    let f_last = draw_rows[4 * (k - 1) + 2].mean_s;
    let factor = (ns[k - 1] / ns[0]) as f64;
    println!(
        "\nscaling {}k -> {}k classes: tree ×{:.2}, flat+logits ×{:.2} (linear would be ×{:.0})",
        ns[0] / 1000,
        ns[k - 1] / 1000,
        t_last / t_first,
        f_last / f_first,
        factor
    );

    // machine-readable results for the cross-PR perf trajectory
    write_json(
        "sampling",
        &[
            ("per-example draw cost", &draw_rows),
            ("batch engine vs per-example loop", &batch_rows),
            ("batched descent vs feature dim", &descent_rows),
            ("per-class update cost", &update_rows),
        ],
    );
}
