//! Structured orthogonal random features: blockwise-orthogonalized `ω`.
//!
//! Drawing the D frequency rows iid N(0, I_d) makes the per-feature kernel
//! estimates independent; coupling the rows of each d-sized block to be
//! mutually *orthogonal* (while keeping each row's marginal N(0, I_d))
//! provably reduces the variance of `⟨φ(a), φ(b)⟩` around `exp(aᵀb)` at
//! equal D (Yu et al., "Orthogonal Random Features", 2016; Choromanski et
//! al., 2017 extend it to positive features). The construction:
//!
//! 1. split the D rows into ⌈D/d⌉ blocks of at most d rows;
//! 2. per block, draw Gaussian rows and Gram–Schmidt them against the
//!    block's previous rows (redrawing on degeneracy, which happens with
//!    probability 0);
//! 3. rescale each orthonormal direction by the norm of an *independent*
//!    iid N(0, I_d) vector, so the row's marginal distribution is exactly
//!    N(0, I_d) again (a uniformly random direction times a χ_d radius).
//!
//! The unbiasedness proof of the positive feature map only uses the
//! marginal law of each `ω_i`, so orthogonalization changes variance, not
//! expectation — the property tests check both.

use crate::ops;
use crate::util::rng::Rng;

/// Squared Euclidean norm of an f64 slice (the ops-layer dot with itself).
fn sq_norm(v: &[f64]) -> f64 {
    ops::dot(v, v)
}

/// Draw a `rows × d` row-major frequency matrix whose rows are blockwise
/// orthogonal with exact N(0, I_d) marginals. Deterministic in `rng`.
pub fn draw_orthogonal_omega(rng: &mut Rng, rows: usize, d: usize) -> Vec<f64> {
    let mut omega = vec![0.0f64; rows * d];
    let mut block: Vec<Vec<f64>> = Vec::with_capacity(d);
    for r in 0..rows {
        if r % d == 0 {
            block.clear();
        }
        // Gram–Schmidt a fresh Gaussian row against the block so far;
        // redraw on (measure-zero) degeneracy so the direction is always
        // well-defined.
        let dir = loop {
            let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            for prev in &block {
                let proj = ops::dot(&v, prev);
                ops::axpy(&mut v, -proj, prev);
            }
            let n2 = sq_norm(&v);
            if n2 > 1e-24 {
                let inv = 1.0 / n2.sqrt();
                for vi in v.iter_mut() {
                    *vi *= inv;
                }
                break v;
            }
        };
        // χ_d radius from an independent Gaussian vector restores the
        // N(0, I_d) marginal.
        let radius = (0..d).map(|_| rng.normal()).map(|g| g * g).sum::<f64>().sqrt();
        for (slot, &di) in omega[r * d..(r + 1) * d].iter_mut().zip(dir.iter()) {
            *slot = radius * di;
        }
        block.push(dir);
    }
    omega
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_are_orthogonal_rows() {
        let d = 6;
        let rows = 15; // 2 full blocks + a partial one
        let mut rng = Rng::new(7);
        let omega = draw_orthogonal_omega(&mut rng, rows, d);
        for b in 0..rows.div_ceil(d) {
            let lo = b * d;
            let hi = (lo + d).min(rows);
            for i in lo..hi {
                for j in (i + 1)..hi {
                    let dot: f64 = (0..d)
                        .map(|k| omega[i * d + k] * omega[j * d + k])
                        .sum();
                    assert!(dot.abs() < 1e-9, "rows {i},{j} in block {b}: dot {dot}");
                }
            }
        }
    }

    #[test]
    fn rows_have_chi_d_scale() {
        // E[‖ω_i‖²] = d for N(0, I_d) marginals; check the empirical mean
        // over many rows (σ of the mean ≈ √(2d)/√rows).
        let d = 8;
        let rows = 4000;
        let mut rng = Rng::new(9);
        let omega = draw_orthogonal_omega(&mut rng, rows, d);
        let mean_sq: f64 =
            (0..rows).map(|r| sq_norm(&omega[r * d..(r + 1) * d])).sum::<f64>() / rows as f64;
        assert!((mean_sq - d as f64).abs() < 0.3, "E‖ω‖² = {mean_sq}, want ≈ {d}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = draw_orthogonal_omega(&mut Rng::new(3), 10, 4);
        let b = draw_orthogonal_omega(&mut Rng::new(3), 10, 4);
        assert_eq!(a, b);
    }
}
