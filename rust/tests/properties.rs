//! Property-based tests on the system's invariants (the in-tree harness
//! replaces proptest; failures report a replayable case seed).
//!
//! The central invariants:
//!
//! 1. every sampler's reported q is a real probability and matches `prob()`;
//! 2. the kernel tree is *exactly* the kernel distribution (q closed-form)
//!    under any leaf size, embedding state, and interleaving of updates;
//! 3. the eq. (2) correction pipeline (q -> ln(m q)) is finite whenever
//!    q > 0 — no sampler may emit q = 0;
//! 4. the alias table and CDF sampling agree with their weights;
//! 5. batches are well-formed for every dataset geometry.

use kss::data::{synptb::SynPtb, youtube::YouTube, Dataset};
use kss::sampler::kernel::FeatureMap;
use kss::sampler::{
    build_sampler, row_rng, BatchSampleInput, CorpusStats, KernelTreeSampler, QuadraticMap,
    Sample, SampleInput, Sampler,
};
use kss::util::rng::Rng;
use kss::util::testing::{check, Gen};

fn random_emb(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n * d];
    rng.fill_normal(&mut v, 0.5);
    v
}

#[test]
fn prop_every_sampler_q_is_valid_and_consistent() {
    check("sampler q validity", 30, |g: &mut Gen| {
        let n = g.usize_in(4, 120);
        let d = g.usize_in(1, 8);
        let m = g.usize_in(1, 16);
        let mut rng = Rng::new(g.case_seed ^ 0xAB);
        let emb = random_emb(&mut rng, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let logits: Vec<f32> = (0..n)
            .map(|j| emb[j * d..(j + 1) * d].iter().zip(&h).map(|(&a, &b)| a * b).sum())
            .collect();
        let counts: Vec<u64> = (0..n).map(|_| rng.below(50)).collect();
        let pairs: Vec<Vec<(u32, u64)>> = (0..n)
            .map(|_| {
                (0..g.usize_in(0, 4))
                    .map(|_| (rng.below(n as u64) as u32, 1 + rng.below(9)))
                    .collect()
            })
            .collect();
        let stats = CorpusStats { class_counts: counts, bigram_counts: Some(pairs) };
        for name in [
            "uniform",
            "unigram",
            "bigram",
            "softmax",
            "quadratic",
            "quadratic-sharded",
            "quadratic-flat",
            "quartic",
            "rff",
            "rff-sharded",
            "rff-flat",
        ] {
            let sampler =
                build_sampler(name, n, d, 100.0, false, Some(&stats), Some(&emb)).unwrap();
            let input = SampleInput {
                h: Some(&h),
                logits: Some(&logits),
                prev: Some(rng.below(n as u64) as u32),
            };
            let mut out = Sample::default();
            sampler.sample(&input, m, &mut rng, &mut out).unwrap();
            assert_eq!(out.classes.len(), m, "{name}");
            for (&c, &q) in out.classes.iter().zip(&out.q) {
                assert!((c as usize) < n, "{name}: class oob");
                assert!(q > 0.0 && q <= 1.0 + 1e-12, "{name}: bad q {q}");
                // eq. (2) correction must be finite
                assert!((m as f64 * q).ln().is_finite(), "{name}: ln(mq) blew up");
                // q must agree with prob() where supported
                if let Some(p) = sampler.prob(&input, c) {
                    assert!(
                        (p - q).abs() <= 1e-6 * p.abs().max(1e-12),
                        "{name}: q {q} != prob {p}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_sample_batch_reproduces_per_row_streams_for_every_sampler() {
    // the batch API contract: for every sampler, sample_batch over the
    // row_rng(step_seed, i) streams is bit-identical to the per-example
    // loop, for any thread count — and every reported q is > 0.
    check("sample_batch == per-row sample streams", 12, |g: &mut Gen| {
        let n_classes = g.usize_in(4, 80);
        let d = g.usize_in(1, 6);
        let rows = g.usize_in(1, 12);
        let m = g.usize_in(1, 8);
        let threads = g.usize_in(0, 8);
        let mut rng = Rng::new(g.case_seed ^ 0x5A);
        let emb = random_emb(&mut rng, n_classes, d);
        let mut hs = vec![0.0f32; rows * d];
        rng.fill_normal(&mut hs, 1.0);
        let logits: Vec<f32> = (0..rows)
            .flat_map(|i| {
                let h = &hs[i * d..(i + 1) * d];
                (0..n_classes)
                    .map(|j| {
                        emb[j * d..(j + 1) * d].iter().zip(h).map(|(&a, &b)| a * b).sum::<f32>()
                    })
                    .collect::<Vec<_>>()
            })
            .collect();
        let prevs: Vec<u32> = (0..rows).map(|_| rng.below(n_classes as u64) as u32).collect();
        let counts: Vec<u64> = (0..n_classes).map(|_| rng.below(50)).collect();
        let pairs: Vec<Vec<(u32, u64)>> = (0..n_classes)
            .map(|_| {
                (0..g.usize_in(0, 3))
                    .map(|_| (rng.below(n_classes as u64) as u32, 1 + rng.below(9)))
                    .collect()
            })
            .collect();
        let stats = CorpusStats { class_counts: counts, bigram_counts: Some(pairs) };
        let step_seed = g.case_seed ^ 0x77;
        for name in [
            "uniform",
            "unigram",
            "bigram",
            "softmax",
            "quadratic",
            "quadratic-sharded",
            "quadratic-flat",
            "quartic",
            "rff",
            "rff-sharded",
            "rff-flat",
        ] {
            let sampler =
                build_sampler(name, n_classes, d, 100.0, false, Some(&stats), Some(&emb)).unwrap();
            let inputs = BatchSampleInput {
                n: rows,
                d,
                n_classes,
                h: Some(&hs),
                logits: Some(&logits),
                prev: Some(&prevs),
                threads,
            };
            let mut batched: Vec<Sample> = (0..rows).map(|_| Sample::default()).collect();
            sampler.sample_batch(&inputs, m, step_seed, &mut batched).unwrap();
            for i in 0..rows {
                let input = inputs.row(i);
                let mut r = row_rng(step_seed, i);
                let mut want = Sample::default();
                sampler.sample(&input, m, &mut r, &mut want).unwrap();
                assert_eq!(batched[i].classes, want.classes, "{name} row {i}");
                assert_eq!(batched[i].q, want.q, "{name} row {i}");
                for &q in &batched[i].q {
                    assert!(q > 0.0 && q.is_finite(), "{name}: bad q {q}");
                }
            }
        }
    });
}

#[test]
fn prop_tree_equals_flat_distribution_under_updates() {
    check("tree == closed-form kernel distribution after updates", 20, |g: &mut Gen| {
        let n = g.usize_in(2, 64);
        let d = g.usize_in(1, 6);
        let leaf = g.usize_in(1, n);
        let mut rng = Rng::new(g.case_seed ^ 0xCD);
        let mut emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, g.f64_in(0.5, 150.0));
        let mut tree = KernelTreeSampler::new(map.clone(), n, Some(leaf));
        tree.reset_embeddings(&emb, n, d);
        // interleave updates and checks
        for _ in 0..g.usize_in(0, 30) {
            let class = rng.range(0, n);
            let mut w = vec![0.0f32; d];
            rng.fill_normal(&mut w, 0.7);
            emb[class * d..(class + 1) * d].copy_from_slice(&w);
            tree.update(class, &w);
        }
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let weights: Vec<f64> =
            (0..n).map(|j| map.kernel(&h, &emb[j * d..(j + 1) * d])).collect();
        let z: f64 = weights.iter().sum();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        tree.sample(&input, 16, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            let want = weights[c as usize] / z;
            assert!((q - want).abs() < 1e-6 * want.max(1e-12), "q {q} vs {want}");
        }
        // drift bound
        assert!(tree.max_drift() < 1e-8, "drift {}", tree.max_drift());
    });
}

#[test]
fn prop_synptb_batches_are_well_formed() {
    check("synptb batch invariants", 15, |g: &mut Gen| {
        let n = g.usize_in(10, 300);
        let b = g.usize_in(1, 6);
        let t = g.usize_in(1, 12);
        let train = g.usize_in(b * (t + 1), 4_000);
        let ds = SynPtb::generate(n, b, t, train, train / 4 + t * b + b, g.case_seed);
        for batch in ds.train_batches(0).iter().chain(ds.eval_batches().iter()) {
            assert_eq!(batch.pos.len(), b * t);
            assert_eq!(batch.data[0].shape(), &[b, t]);
            assert_eq!(batch.data[1].shape(), &[b, t]);
            let tokens = batch.data[0].as_i32().unwrap();
            let targets = batch.data[1].as_i32().unwrap();
            for (&tok, &tgt) in tokens.iter().zip(targets) {
                assert!((tok as usize) < n && (tgt as usize) < n);
            }
            let prev = batch.prev.as_ref().unwrap();
            for (&p, &tok) in prev.iter().zip(tokens) {
                assert_eq!(p as i32, tok, "prev context must be the input token");
            }
        }
        let stats = ds.stats();
        assert_eq!(stats.class_counts.iter().sum::<u64>() as usize, ds.train_tokens().len());
    });
}

#[test]
fn prop_youtube_batches_are_well_formed() {
    check("youtube batch invariants", 15, |g: &mut Gen| {
        let n = g.usize_in(8, 600);
        let f = g.usize_in(2, 8);
        let b = g.usize_in(1, 8);
        let events = g.usize_in(b, 3_000);
        let ds = YouTube::generate(n, f, events, events / 4 + b, b, g.case_seed);
        let batches = ds.train_batches(0);
        assert_eq!(batches.len(), events / b);
        for batch in batches.iter().take(5) {
            assert_eq!(batch.data[0].shape(), &[b, f]);
            assert_eq!(batch.data[1].shape(), &[b, 3]);
            for &p in batch.data[1].as_i32().unwrap() {
                assert!((p as usize) < n);
            }
            for &p in &batch.pos {
                assert!((p as usize) < n);
            }
            assert!(batch.prev.is_none());
        }
    });
}

#[test]
fn prop_uniform_correction_recovers_partition_function() {
    // E_q[ K(h,w)/q ] = Σ K — the identity kernel sampling is built on
    // (eq. 8/12), checked by Monte Carlo through the real tree sampler.
    check("importance identity", 8, |g: &mut Gen| {
        let n = g.usize_in(8, 64);
        let d = g.usize_in(2, 5);
        let mut rng = Rng::new(g.case_seed ^ 0xEF);
        let emb = random_emb(&mut rng, n, d);
        let map = QuadraticMap::new(d, 100.0);
        let mut tree = KernelTreeSampler::new(map.clone(), n, None);
        tree.reset_embeddings(&emb, n, d);
        let h: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let z_true: f64 = (0..n).map(|j| map.kernel(&h, &emb[j * d..(j + 1) * d])).sum();
        let input = SampleInput { h: Some(&h), ..Default::default() };
        let mut out = Sample::default();
        let trials = 4_000;
        let mut acc = 0.0;
        tree.sample(&input, trials, &mut rng, &mut out).unwrap();
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            acc += map.kernel(&h, &emb[c as usize * d..(c as usize + 1) * d]) / q;
        }
        let est = acc / trials as f64;
        assert!((est - z_true).abs() < 0.15 * z_true, "est {est} vs {z_true}");
    });
}

#[test]
fn prop_histogram_quantile_bounded_and_merge_exact() {
    // 6. the obs histogram is a faithful summary: quantile readout within
    //    half the widest sub-bucket (6.25%) of the exact order statistic,
    //    and snapshot merge identical to interleaved recording
    use kss::obs::Histogram;
    check("histogram summary fidelity", 20, |g: &mut Gen| {
        let n = g.usize_in(50, 800);
        let mut rng = Rng::new(g.case_seed ^ 0x0B5);
        let mut vals: Vec<f64> = (0..n).map(|_| 2f64.powf(rng.f64() * 40.0 - 26.0)).collect();
        let whole = Histogram::new();
        let (a, b) = (Histogram::new(), Histogram::new());
        for (i, &v) in vals.iter().enumerate() {
            whole.record(v);
            if i % 2 == 0 { &a } else { &b }.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let s = whole.snapshot();
        assert_eq!(merged.buckets(), s.buckets(), "merge != interleaved");
        assert_eq!(merged.count(), s.count());
        assert_eq!(merged.min(), s.min());
        assert_eq!(merged.max(), s.max());
        vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for &q in &[0.1, 0.5, 0.95, 0.99] {
            let rank = ((q * n as f64).ceil() as usize).max(1);
            let exact = vals[rank - 1];
            let got = s.quantile(q);
            assert!(
                (got - exact).abs() / exact <= 0.0625,
                "q {q}: {got} vs exact {exact}"
            );
        }
    });
}

#[test]
fn prop_monitor_estimators_match_exact_stats() {
    // 7. the streaming monitors agree with util::stats ground truth:
    //    uniform-proposal TV is exact, and ESS/m = 1 iff o = ln(m q)
    use kss::obs::{ess_fraction, tv_from_pairs};
    use kss::util::stats::tv_distance;
    check("monitor estimators vs exact stats", 20, |g: &mut Gen| {
        let n = g.usize_in(4, 96);
        let mut rng = Rng::new(g.case_seed ^ 0xE55);
        let o: Vec<f64> = (0..n).map(|_| rng.f64() * 6.0 - 3.0).collect();
        let max_o = o.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = o.iter().map(|&x| (x - max_o).exp()).collect();
        let z: f64 = e.iter().sum();
        let p: Vec<f64> = e.iter().map(|&x| x / z).collect();
        let uniform = vec![1.0 / n as f64; n];
        let pairs: Vec<(f64, f64)> = o.iter().map(|&oi| (oi, 1.0 / n as f64)).collect();
        let got = tv_from_pairs(&pairs).unwrap();
        let exact = tv_distance(&p, &uniform);
        assert!((got - exact).abs() < 1e-10, "TV {got} vs exact {exact}");
        // matched proposal: o_i = ln(m q_i) gives uniform eq. (2) weights
        let scored: Vec<(f64, f64)> =
            p.iter().map(|&pi| ((n as f64 * pi).ln(), pi)).collect();
        let f = ess_fraction(&scored).unwrap();
        assert!((f - 1.0).abs() < 1e-10, "matched-proposal ESS fraction {f}");
        // and q == p makes the TV estimate vanish
        let exact_pairs: Vec<(f64, f64)> =
            o.iter().zip(&p).map(|(&oi, &pi)| (oi, pi)).collect();
        assert!(tv_from_pairs(&exact_pairs).unwrap() < 1e-10);
    });
}
