// pallas-lint fixture — must NOT trip QPOS: one function per accepted
// guard idiom.

/// Guard 1: the denominator is clamped on the division statement.
pub fn clamped(k: f64, total: f64) -> f64 {
    k / total.max(f64::MIN_POSITIVE)
}

/// Guard 2: the divisor is checked positive-and-finite just above.
pub fn checked(k: f64, total: f64) -> f64 {
    if total > 0.0 && total.is_finite() {
        k / total
    } else {
        0.0
    }
}

/// Guard 3: the quotient is validated immediately after the division.
pub fn validated(k: f64, total: f64) -> f64 {
    let q = k / total;
    if q > 0.0 && q.is_finite() {
        q
    } else {
        f64::MIN_POSITIVE
    }
}

/// Guard 4: the divisor was minted by the checked pool-mass constructor
/// (the two-pass sampler idiom, kernel/two_pass.rs): `Some` only for
/// finite, strictly positive totals.
fn positive_pool_mass(total: f64) -> Option<f64> {
    if total > 0.0 && total.is_finite() {
        Some(total)
    } else {
        None
    }
}

pub fn pooled(w: f64, cum_total: f64) -> f64 {
    let Some(pool_mass) = positive_pool_mass(cum_total) else {
        // degenerate pool: the caller redraws through the per-row descent
        return f64::MIN_POSITIVE;
    };
    // a few lines of pass-2 resampling between the mint and the division,
    // as in the real engine (the rule's look-behind spans the scope)
    let u = 0.5 * pool_mass;
    let _ = u;
    w / pool_mass
}

/// The midx two-level idiom (kernel/midx.rs): both denominators of the
/// composed q — the coarse total and the within-cluster refine total —
/// are minted by the checked constructor before their divisions.
pub fn composed_q(inc: f64, w: f64, coarse_total: f64, inner_total: f64) -> f64 {
    let Some(coarse_mass) = positive_pool_mass(coarse_total) else {
        return f64::MIN_POSITIVE;
    };
    let p_coarse = inc / coarse_mass;
    let Some(cluster_mass) = positive_pool_mass(inner_total) else {
        return p_coarse.max(f64::MIN_POSITIVE);
    };
    (p_coarse * (w / cluster_mass)).max(f64::MIN_POSITIVE)
}

/// Divisors that are not mass-like are out of scope for this rule.
pub fn plain_average(sum: f64, len: f64) -> f64 {
    sum / len
}
