"""PANIC — panic-free serve / pipeline workers.

A panic on a worker thread does not crash the process: it kills the
worker, poisons whatever Mutex it held, and leaves the rest of the pool
to either wedge on the poisoned lock or starve the queue — PR 2 shipped
two such bugs (malformed `h`, pathological `m`) that were fixed by hand
with submit-time validation. This rule makes the class extinct: in the
worker code paths (serve batcher, serve worker loop, snapshot reader
sampler, pipeline FIFO worker) it flags

* `.unwrap()` / `.expect(...)` — convert to `ServeError` / `anyhow`
  returns, or recover poisoned locks via `PoisonError::into_inner`;
* `panic!` / `unreachable!` / `todo!` / `unimplemented!`;
* direct slice indexing inside the draw-executing functions (a bad index
  aborts the worker mid-batch; use `.get()` or pre-validated bounds).

`debug_assert!` is allowed (compiled out of release workers); test code
is excluded; deliberate fail-loud sites (thread spawn at startup, the
training driver's crash-on-wedge philosophy) carry waivers.
"""

from __future__ import annotations

from pallas_lint.frontend import IDENT, PUNCT, SourceFile, snippet
from pallas_lint.rules import Finding, Rule

# file -> functions whose bodies are additionally checked for raw indexing
WORKER_FILES = {
    "rust/src/serve/batcher.rs": ("submit", "next_batch", "shutdown", "depth"),
    "rust/src/serve/service.rs": ("worker_loop",),
    "rust/src/serve/reader_sampler.rs": ("sample", "sample_batch", "prob"),
    "rust/src/serve/shard.rs": ("draw_from_shards",),
    "rust/src/coordinator/pipeline.rs": ("spawn",),
    "rust/src/vocab/streaming.rs": ("draw_from_tiers", "prob_from_tiers"),
    "rust/src/vocab/publisher.rs": ("sample", "prob", "refresh_snapshots"),
}

_PANIC_MACROS = {"panic", "unreachable", "todo", "unimplemented"}
_PANIC_METHODS = {"unwrap", "expect"}


class PanicFreeWorkers(Rule):
    id = "PANIC"
    name = "panic-free-workers"
    summary = "unwrap/expect/panic!/raw indexing on worker code paths"
    contract = (
        "serve & pipeline liveness: a panicking worker poisons locks and "
        "wedges the pool — request paths return ServeError, poisoned locks "
        "recover via PoisonError::into_inner (serve/batcher.rs docs)"
    )

    def applies(self, relpath: str) -> bool:
        return relpath in WORKER_FILES

    def check(self, sf: SourceFile) -> list[Finding]:
        findings: list[Finding] = []
        code = sf.code
        index_fns = [
            f
            for f in sf.functions()
            if f.name in WORKER_FILES.get(sf.path, ()) and not sf.in_test(f.start_line)
        ]

        for i, tok in enumerate(code):
            if tok.kind != IDENT or sf.in_test(tok.line):
                continue
            nxt = code[i + 1] if i + 1 < len(code) else None
            prev = code[i - 1] if i > 0 else None
            # .unwrap( / .expect(
            if (
                tok.text in _PANIC_METHODS
                and prev is not None
                and prev.kind == PUNCT
                and prev.text == "."
                and nxt is not None
                and nxt.kind == PUNCT
                and nxt.text == "("
                # a panic inside debug_assert! is compiled out of release
                # workers, same as the assertion itself
                and "debug_assert" not in sf.line_text(tok.line)
            ):
                findings.append(
                    Finding(
                        rule=self.id,
                        file=sf.path,
                        line=tok.line,
                        message=(
                            f".{tok.text}() on a worker code path — return a "
                            "ServeError/anyhow error, or recover a poisoned "
                            "lock with PoisonError::into_inner"
                        ),
                        snippet=snippet(sf, tok.line),
                    )
                )
                continue
            # panic-family macros
            if (
                tok.text in _PANIC_MACROS
                and nxt is not None
                and nxt.kind == PUNCT
                and nxt.text == "!"
            ):
                findings.append(
                    Finding(
                        rule=self.id,
                        file=sf.path,
                        line=tok.line,
                        message=(
                            f"{tok.text}! on a worker code path — a worker "
                            "panic wedges the pool; surface an error instead"
                        ),
                        snippet=snippet(sf, tok.line),
                    )
                )

        # raw indexing inside the draw-executing functions
        seen: set[int] = set()
        for fn in index_fns:
            for j in range(fn.body_open + 1, fn.body_close):
                t = code[j]
                if not (t.kind == PUNCT and t.text == "["):
                    continue
                prev = code[j - 1]
                # indexing (ident[..], )[..], ][..]) vs array literal / attr
                if not (
                    prev.kind == IDENT or (prev.kind == PUNCT and prev.text in ")]")
                ):
                    continue
                if prev.kind == IDENT and prev.text in ("vec",):  # vec![...]
                    continue
                if t.line in seen or sf.in_test(t.line):
                    continue
                seen.add(t.line)
                findings.append(
                    Finding(
                        rule=self.id,
                        file=sf.path,
                        line=t.line,
                        message=(
                            f"raw slice indexing inside `{fn.name}` (worker draw "
                            "path) — an out-of-bounds index aborts the worker; "
                            "use .get() or bounds validated at submit time"
                        ),
                        snippet=snippet(sf, t.line),
                    )
                )
        return findings
