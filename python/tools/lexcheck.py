#!/usr/bin/env python3
"""Delimiter-balance lexer for Rust sources (offline compile sanity).

The build container has no rust toolchain, so this script provides the
cheapest mechanical check a compiler would do first: every `(`/`[`/`{` is
closed by the matching delimiter, with string literals (including raw
strings), char literals, lifetimes, and comments handled so they cannot
produce false positives. Run:

    python3 python/tools/lexcheck.py $(git ls-files '*.rs')
"""
import sys


def lex(path: str) -> list[str]:
    src = open(path, encoding="utf-8").read()
    errs = []
    stack = []  # (char, line)
    pairs = {")": "(", "]": "[", "}": "{"}
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        # line comment
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            while i < n and src[i] != "\n":
                i += 1
            continue
        # block comment (nested)
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            depth, i = 1, i + 2
            while i < n and depth:
                if src[i] == "\n":
                    line += 1
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    i += 1
            continue
        # raw string r"..." / r#"..."# / br#"..."#
        if c in "rb":
            j = i
            if src[j] == "b":
                j += 1
            if j < n and src[j] == "r":
                k = j + 1
                hashes = 0
                while k < n and src[k] == "#":
                    hashes += 1
                    k += 1
                if k < n and src[k] == '"':
                    end = '"' + "#" * hashes
                    e = src.find(end, k + 1)
                    if e < 0:
                        errs.append(f"{path}:{line}: unterminated raw string")
                        return errs
                    line += src.count("\n", i, e)
                    i = e + len(end)
                    continue
        # plain string (b"..." too)
        if c == '"' or (c == "b" and i + 1 < n and src[i + 1] == '"'):
            i += 2 if c == "b" else 1
            while i < n:
                if src[i] == "\\":
                    i += 2
                    continue
                if src[i] == "\n":
                    line += 1
                if src[i] == '"':
                    i += 1
                    break
                i += 1
            continue
        # char literal vs lifetime: 'a' is a char, 'a (no closing quote
        # within 2-3 chars, or followed by ident) is a lifetime
        if c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                e = src.find("'", i + 2)
                i = (e + 1) if e > 0 else i + 2
                continue
            if i + 2 < n and src[i + 2] == "'":
                i += 3
                continue
            i += 1  # lifetime
            continue
        if c in "([{":
            stack.append((c, line))
            i += 1
            continue
        if c in ")]}":
            if not stack:
                errs.append(f"{path}:{line}: unmatched '{c}'")
            elif stack[-1][0] != pairs[c]:
                o, ol = stack[-1]
                errs.append(f"{path}:{line}: '{c}' closes '{o}' opened at line {ol}")
                stack.pop()
            else:
                stack.pop()
            i += 1
            continue
        i += 1
    for o, ol in stack:
        errs.append(f"{path}:{ol}: unclosed '{o}'")
    return errs


def main() -> int:
    bad = 0
    for path in sys.argv[1:]:
        for e in lex(path):
            print(e)
            bad += 1
    print(f"lexcheck: {len(sys.argv) - 1} files, {bad} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
