//! Figure 6 (appendix) — **three datasets × three samplers × m sweep**
//! convergence curves (uniform / quadratic / softmax on PTB, YouTube10k,
//! YouTube100k).
//!
//! `cargo bench --bench fig6_datasets` / `KSS_BENCH_SCALE=full ...`

use kss::bench_harness::{engine_or_exit, print_series, scale, Scale};
use kss::coordinator::experiment::{run_grid, GridSpec};
use kss::coordinator::TrainConfig;

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    let (datasets, ms): (Vec<(&str, TrainConfig)>, Vec<usize>) = match scale() {
        Scale::Quick => (
            vec![
                (
                    "tiny-recsys",
                    TrainConfig {
                        model: "tiny".into(),
                        epochs: 3,
                        train_size: 960,
                        valid_size: 320,
                        eval_batches: 8,
                        ..Default::default()
                    },
                ),
                (
                    "tiny-lm",
                    TrainConfig {
                        model: "tiny-lm".into(),
                        epochs: 2,
                        train_size: 4_000,
                        valid_size: 1_000,
                        eval_batches: 8,
                        ..Default::default()
                    },
                ),
            ],
            vec![4],
        ),
        Scale::Full => (
            vec![
                (
                    "ptb",
                    TrainConfig {
                        model: "ptb".into(),
                        epochs: 2,
                        train_size: 120_000,
                        valid_size: 24_000,
                        eval_batches: 8,
                        eval_every: 100,
                        ..Default::default()
                    },
                ),
                (
                    "yt10k",
                    TrainConfig {
                        model: "yt10k".into(),
                        epochs: 2,
                        train_size: 40_000,
                        valid_size: 6_400,
                        eval_batches: 8,
                        eval_every: 150,
                        ..Default::default()
                    },
                ),
                (
                    "yt100k",
                    TrainConfig {
                        model: "yt100k".into(),
                        epochs: 1,
                        train_size: 40_000,
                        valid_size: 6_400,
                        eval_batches: 8,
                        eval_every: 150,
                        ..Default::default()
                    },
                ),
            ],
            vec![8, 32, 128],
        ),
    };

    for (label, base) in &datasets {
        for sampler in ["uniform", "quadratic", "softmax"] {
            println!("\n==== Figure 6 — {label} / {sampler} ====");
            let grid = GridSpec {
                base: base.clone(),
                samplers: vec![sampler.to_string()],
                ms: ms.clone(),
                include_full: false,
            };
            let summaries = run_grid(&engine, &grid, Some(std::path::Path::new("runs/fig6")))?;
            for s in &summaries {
                let pts: Vec<(f64, f64)> = s.curve.iter().map(|p| (p.epoch, p.loss)).collect();
                print_series(&format!("{label}/{sampler}/m={}", s.m), &pts);
            }
        }
    }
    println!("\nshape to check: same story on every dataset — m moves the bias");
    println!("floor for uniform/quadratic, never the convergence speed much.");
    Ok(())
}
