//! §3.3 ablation — **absolute softmax vs standard softmax as the prediction
//! distribution**.
//!
//! The paper pairs the (symmetric) quadratic kernel with an absolute-softmax
//! prediction distribution and reports that, trained *without* sampling,
//! absolute and standard softmax "performed very similarly". This bench
//! reproduces that claim (full-softmax training on both variants) and then
//! shows the pairing matters: quadratic sampling under the abs model vs the
//! standard model.
//!
//! `cargo bench --bench ablation_abs_softmax`

use kss::bench_harness::{engine_or_exit, scale, Scale};
use kss::coordinator::{MetricsSink, TrainConfig, Trainer};

fn main() -> anyhow::Result<()> {
    kss::util::logging::init_from_env();
    let engine = engine_or_exit();
    let (std_model, abs_model, epochs, train, valid, m) = match scale() {
        Scale::Quick => ("tiny", "tiny-abs", 3usize, 1_280usize, 320usize, 4usize),
        Scale::Full => ("yt10k", "yt10k-abs", 2, 40_000, 6_400, 32),
    };

    let run = |model: &str, sampler: &str, m: usize| -> anyhow::Result<f64> {
        let cfg = TrainConfig {
            model: model.into(),
            sampler: sampler.into(),
            m,
            epochs,
            train_size: train,
            valid_size: valid,
            eval_batches: 10,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&engine, cfg)?;
        let mut sink = MetricsSink::memory(&format!("{model}-{sampler}"));
        Ok(trainer.train(&mut sink)?.final_loss)
    };

    println!("==== §3.3 ablation: absolute vs standard softmax ====\n");
    let full_std = run(std_model, "full", 0)?;
    let full_abs = run(abs_model, "full", 0)?;
    println!("full-softmax training ({epochs} epochs):");
    println!("  standard softmax   eval loss {full_std:.4}");
    println!("  absolute softmax   eval loss {full_abs:.4}");
    let rel = (full_std - full_abs).abs() / full_std;
    println!(
        "  relative gap {:.2}% -> {}",
        rel * 100.0,
        if rel < 0.05 { "PASS: 'performed very similarly' (paper §3.3)" } else { "FAIL" }
    );

    println!("\nquadratic-kernel sampling (m = {m}) under each prediction distribution:");
    let quad_std = run(std_model, "quadratic", m)?;
    let quad_abs = run(abs_model, "quadratic", m)?;
    println!("  standard model     eval loss {quad_std:.4}");
    println!("  absolute model     eval loss {quad_abs:.4}");
    println!("(the paper recommends the absolute model for symmetric kernels: the");
    println!(" kernel oversamples negative-logit classes under standard softmax)");
    Ok(())
}
