//! Measure the *gradient bias* of sampled softmax directly (Theorem 2.1 /
//! eq. 5-7): for a fixed model state, Monte-Carlo-estimate
//!
//!   E[ ∂L(p', y')/∂o ]   vs   ∂L(p, y)/∂o = p − y
//!
//! for each sampling distribution and sample size m. Softmax sampling is
//! provably unbiased (the estimate converges to zero bias as trials grow);
//! every other distribution has a residual bias that shrinks with m — the
//! quadratic kernel's is far smaller than uniform's. This is the paper's
//! §2.3 story in one table, computed on the real samplers (including the
//! divide-and-conquer tree).
//!
//! ```sh
//! cargo run --release --example sampler_bias
//! ```

use kss::sampler::{
    FlatKernelSampler, KernelKind, KernelTreeSampler, PositiveRffMap, QuadraticMap, RffConfig,
    Sample, SampleInput, Sampler, SoftmaxSampler, UniformSampler,
};
use kss::util::rng::Rng;

const N: usize = 200; // classes
const D: usize = 16; // embedding dim
const TRIALS: usize = 30_000;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(11);
    // a "trained-ish" model state: logits with meaningful spread
    let mut w = vec![0.0f32; N * D];
    rng.fill_normal(&mut w, 0.5);
    let h: Vec<f32> = (0..D).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let logits: Vec<f32> = (0..N)
        .map(|j| w[j * D..(j + 1) * D].iter().zip(&h).map(|(&a, &b)| a * b).sum())
        .collect();
    let positive = 3u32;

    // full softmax gradient wrt logits: p - y
    let p = softmax(&logits);
    let mut full_grad = p.clone();
    full_grad[positive as usize] -= 1.0;

    let mut tree = KernelTreeSampler::new(QuadraticMap::new(D, 100.0), N, None);
    tree.reset_embeddings(&w, N, D);
    // the rff tree at the registry default D = 4d: exp-kernel proposals
    // through the same divide-and-conquer machinery
    let mut rff_tree =
        KernelTreeSampler::new(PositiveRffMap::new(RffConfig::new(D, 0x2FF)), N, None);
    rff_tree.reset_embeddings(&w, N, D);
    let samplers: Vec<Box<dyn Sampler>> = vec![
        Box::new(UniformSampler::new(N)),
        Box::new(FlatKernelSampler::new(KernelKind::Quadratic { alpha: 100.0 })),
        Box::new(tree),
        Box::new(FlatKernelSampler::new(KernelKind::Quartic)),
        Box::new(rff_tree),
        Box::new(FlatKernelSampler::new(KernelKind::Exp)),
        Box::new(SoftmaxSampler::new(N, false)),
    ];

    println!("gradient bias ‖E[ĝ] − (p − y)‖₁  ({N} classes, {TRIALS} trials/cell)\n");
    print!("{:<18}", "sampler");
    let ms = [2usize, 8, 32, 128];
    for m in ms {
        print!(" {:>9}", format!("m={m}"));
    }
    println!();
    for sampler in &samplers {
        print!("{:<18}", sampler.name());
        for m in ms {
            let bias = measure_bias(sampler.as_ref(), &h, &logits, positive, &full_grad, m, &mut rng);
            print!(" {:>9.4}", bias);
        }
        println!();
    }
    println!(
        "\nExpected shape (paper §2.3/Thm 2.1): softmax ≈ 0 at every m (only\n\
         Monte-Carlo noise); rff-flat (= exp kernel = softmax) ≈ 0 too; the\n\
         rff tree near it, quadratic/quartic well below uniform; all biased\n\
         samplers improve as m grows."
    );
    Ok(())
}

fn softmax(o: &[f32]) -> Vec<f64> {
    let mx = o.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let e: Vec<f64> = o.iter().map(|&x| (x as f64 - mx).exp()).collect();
    let z: f64 = e.iter().sum();
    e.into_iter().map(|x| x / z).collect()
}

/// Monte-Carlo E[sampled gradient wrt the original logits], L1 bias.
fn measure_bias(
    sampler: &dyn Sampler,
    h: &[f32],
    logits: &[f32],
    positive: u32,
    full_grad: &[f64],
    m: usize,
    rng: &mut Rng,
) -> f64 {
    let n = logits.len();
    let input = SampleInput { h: Some(h), logits: Some(logits), prev: None };
    let mut acc = vec![0.0f64; n];
    let mut out = Sample::default();
    for _ in 0..TRIALS {
        sampler.sample(&input, m, rng, &mut out).expect("sample");
        // adjusted logits o' (eq. 2): positive at slot 0 uncorrected
        let mut adj = Vec::with_capacity(m + 1);
        adj.push(logits[positive as usize] as f64);
        for (&c, &q) in out.classes.iter().zip(&out.q) {
            adj.push(logits[c as usize] as f64 - (m as f64 * q).ln());
        }
        let mx = adj.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = adj.iter().map(|&x| (x - mx).exp()).collect();
        let z: f64 = e.iter().sum();
        // scatter p' - y' back to original logit space (eq. 5)
        acc[positive as usize] += e[0] / z - 1.0;
        for (k, &c) in out.classes.iter().enumerate() {
            acc[c as usize] += e[k + 1] / z;
        }
    }
    acc.iter()
        .zip(full_grad)
        .map(|(a, g)| (a / TRIALS as f64 - g).abs())
        .sum()
}
