"""AOT compile path: lower every entry point to HLO text + a manifest.

This is the only place Python touches the system; it runs once at build time
(`make artifacts`). For each model configuration in ``configs.py`` it lowers

    encode, score_all, eval_full, train_full, train_sampled[m ...]

to ``artifacts/<config>_<op>[ _m<m> ].hlo.txt`` and records everything the
rust runtime needs — parameter order/shape/init, input and output specs per
artifact — in ``artifacts/manifest.json``.

HLO *text* is the interchange format on purpose: jax >= 0.5 serializes
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
runtime's PJRT build) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts            # default set
    python -m compile.aot --out-dir ../artifacts --quick    # tiny configs
    python -m compile.aot --configs ptb,yt10k --m 8,32
"""

import argparse
import json
import os
import sys
import time

from . import configs as C
from . import model as M

OPS_SHARED = ["encode", "score_all", "eval_full", "train_full"]


def artifact_filename(cfg_name, op, m=None):
    suffix = f"_m{m}" if m is not None else ""
    return f"{cfg_name}_{op}{suffix}.hlo.txt"


def lower_one(cfg, op, m, out_dir, force=False):
    """Lower one entry point; returns (filename, seconds, skipped)."""
    fname = artifact_filename(cfg.name, op, m)
    path = os.path.join(out_dir, fname)
    if not force and os.path.exists(path) and os.path.getsize(path) > 0:
        return fname, 0.0, True
    t0 = time.time()
    text = M.lower_to_hlo_text(cfg, op, m)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return fname, time.time() - t0, False


def manifest_entry(cfg, build_ms, files):
    """Manifest record for one model config."""
    return {
        "model": cfg.model,
        "n_classes": cfg.n_classes,
        "d": cfg.d,
        "batch": cfg.batch,
        "seq_len": cfg.seq_len,
        "n_user_features": cfg.n_user_features,
        "n_prev": cfg.n_prev,
        "hidden": cfg.hidden,
        "n_examples": cfg.n_examples,
        "abs_logits": cfg.abs_logits,
        "alpha": cfg.alpha,
        "params": [
            {"name": name, "shape": list(shape), "init": init}
            for name, shape, init in cfg.param_specs()
        ],
        "ops": {
            op: {
                "file": files[(op, None)],
                "inputs": [
                    {"name": n, "dtype": t, "shape": list(s)}
                    for n, t, s in cfg.data_specs(op)
                ],
                "outputs": [
                    {"name": n, "dtype": t, "shape": list(s)}
                    for n, t, s in cfg.output_specs(op)
                ],
            }
            for op in OPS_SHARED
        },
        "train_sampled": {
            str(m): {
                "file": files[("train_sampled", m)],
                "inputs": [
                    {"name": n, "dtype": t, "shape": list(s)}
                    for n, t, s in cfg.data_specs("train_sampled", m)
                ],
                "outputs": [
                    {"name": n, "dtype": t, "shape": list(s)}
                    for n, t, s in cfg.output_specs("train_sampled", m)
                ],
            }
            for m in build_ms
        },
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default=None, help="comma list of config names (default: build table)")
    ap.add_argument("--m", default=None, help="comma list of sample sizes m")
    ap.add_argument("--quick", action="store_true", help="tiny configs only (tests/CI)")
    ap.add_argument("--force", action="store_true", help="re-lower even if the file exists")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    build = dict(C.QUICK_BUILD if args.quick else C.DEFAULT_BUILD)
    if args.configs:
        names = [c.strip() for c in args.configs.split(",") if c.strip()]
        for n in names:
            if n not in C.CONFIGS:
                sys.exit(f"unknown config '{n}' (known: {', '.join(C.CONFIGS)})")
        ms = [int(x) for x in args.m.split(",")] if args.m else C.M_SWEEP
        build = {n: ms for n in names}

    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "models": {}}
    # Merge with an existing manifest so partial builds extend it.
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("version") == 1:
                manifest["models"].update(old.get("models", {}))
        except (json.JSONDecodeError, OSError):
            pass

    total_t = 0.0
    for cfg_name, ms in build.items():
        cfg = C.CONFIGS[cfg_name]
        files = {}
        for op in OPS_SHARED:
            fname, dt, skipped = lower_one(cfg, op, None, out_dir, args.force)
            files[(op, None)] = fname
            total_t += dt
            print(f"  {fname:<44} {'cached' if skipped else f'{dt:6.1f}s'}", flush=True)
        for m in ms:
            fname, dt, skipped = lower_one(cfg, "train_sampled", m, out_dir, args.force)
            files[("train_sampled", m)] = fname
            total_t += dt
            print(f"  {fname:<44} {'cached' if skipped else f'{dt:6.1f}s'}", flush=True)
        # Merge m-entries if the config was already in the manifest.
        entry = manifest_entry(cfg, ms, files)
        prev = manifest["models"].get(cfg_name)
        if prev is not None:
            merged = dict(prev.get("train_sampled", {}))
            merged.update(entry["train_sampled"])
            entry["train_sampled"] = merged
        manifest["models"][cfg_name] = entry

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path} ({len(manifest['models'])} models, lowering took {total_t:.1f}s)")


if __name__ == "__main__":
    main()
