"""QPOS — eq. (2) q-positivity: no unguarded division by kernel mass.

Sampled softmax is unbiased only when every reported q is exact and
strictly positive (`Sample::push` debug-asserts it; the trainer feeds
`ln(m·q)` to the loss). Kernel masses and partition totals can underflow
to zero or go non-finite, so the draw paths route every division through
`choose_branch` / `sanitize_mass` / `.max(f64::MIN_POSITIVE)` guards.
This rule flags a raw division whose divisor is a mass/total/partition
quantity with none of the guard patterns in sight:

* the result is clamped: `.max(f64::MIN_POSITIVE)` on the same statement;
* the divisor was checked: `<divisor> > 0.0` / `is_finite` in the
  enclosing few lines (branch guards like `if total > 0.0 && ...`);
* the quotient is validated right after: `q > 0.0 && q.is_finite()`;
* the divisor was minted by a checked pool-mass constructor:
  `let Some(<divisor>) = positive_pool_mass(...) else { ... }` — the
  two-pass sampler's guard idiom (kernel/two_pass.rs), which proves
  positivity and finiteness for every division in the scope below.

Diagnostic-only divisions (closed-form oracles in tests) are excluded by
the test-span filter; surviving cold-path sites carry waivers.
"""

from __future__ import annotations

import re

from pallas_lint.frontend import IDENT, NUM, PUNCT, SourceFile, snippet
from pallas_lint.rules import Finding, Rule

_MASS_NAME = re.compile(r"(?:^|_)(mass|masses|total|totals|partition|denom)(?:$|_)")

_GUARD_BEFORE = 8  # lines of look-behind for a divisor positivity check
_GUARD_AFTER = 6  # lines of look-ahead for a quotient validation
# look-behind for a `let Some(x) = positive_pool_mass(..)` minting — the
# let-else proves the name for its whole scope, so the window is wider
# than the plain positivity guards
_GUARD_POOL_BEFORE = 28


class QPositivity(Rule):
    id = "QPOS"
    name = "q-positivity"
    summary = "unguarded division by kernel mass / partition total"
    contract = (
        "eq. (2) exactness: q must stay finite and strictly positive; "
        "divisions by subtree/leaf mass go through choose_branch or the "
        "sanitize_mass/MIN_POSITIVE guards (sampler/kernel/tree.rs)"
    )

    def applies(self, relpath: str) -> bool:
        return (
            relpath.startswith("rust/src/sampler/")
            or relpath.startswith("rust/src/serve/")
            or relpath.startswith("rust/src/vocab/")
        )

    def _divisor_chain(self, sf: SourceFile, idx: int) -> str:
        """Dotted ident chain starting at code[idx] (the token after `/`)."""
        code = sf.code
        parts = []
        j = idx
        while j < len(code):
            t = code[j]
            if t.kind == IDENT:
                parts.append(t.text)
                j += 1
                # skip an index expression after the ident
                if j < len(code) and code[j].kind == PUNCT and code[j].text == "[":
                    depth = 0
                    while j < len(code):
                        if code[j].kind == PUNCT and code[j].text == "[":
                            depth += 1
                        elif code[j].kind == PUNCT and code[j].text == "]":
                            depth -= 1
                            if depth == 0:
                                j += 1
                                break
                        j += 1
                if j < len(code) and code[j].kind == PUNCT and code[j].text == ".":
                    j += 1
                    continue
            break
        return ".".join(parts)

    def check(self, sf: SourceFile) -> list[Finding]:
        findings: list[Finding] = []
        code = sf.code
        for i, tok in enumerate(code):
            if not (tok.kind == PUNCT and tok.text == "/"):
                continue
            if sf.in_test(tok.line):
                continue
            # must be a binary division: something dividable on the left
            if i == 0:
                continue
            prev = code[i - 1]
            if not (
                prev.kind in (IDENT, NUM)
                or (prev.kind == PUNCT and prev.text in ")]")
            ):
                continue
            if i + 1 >= len(code) or code[i + 1].kind != IDENT:
                continue
            chain = self._divisor_chain(sf, i + 1)
            if not chain:
                continue
            last = chain.split(".")[-1]
            if not _MASS_NAME.search(last):
                continue
            line = tok.line
            # guard 1: clamped result on this or the next line
            stmt = sf.window(line, before=0, after=1)
            if "MIN_POSITIVE" in stmt:
                continue
            # guard 2: divisor checked positive/finite just above
            behind = sf.window(line, before=_GUARD_BEFORE)
            if re.search(rf"\b{re.escape(last)}\b\s*>\s*0(\.0)?", behind) or re.search(
                rf"\b{re.escape(last)}\s*\.\s*is_finite", behind
            ):
                continue
            # guard 3: the quotient is validated right after
            #   let q = k / total;  ...  if q > 0.0 && q.is_finite()
            mline = sf.line_text(line)
            m = re.search(r"let\s+(?:mut\s+)?(\w+)\s*=", mline)
            ahead = sf.window(line, after=_GUARD_AFTER)
            if m:
                q = m.group(1)
                if re.search(rf"\b{re.escape(q)}\b\s*>\s*0(\.0)?", ahead) and re.search(
                    rf"\b{re.escape(q)}\s*\.\s*is_finite", ahead
                ):
                    continue
            # guard 4: divisor minted by the checked pool-mass constructor
            #   let Some(pool_mass) = positive_pool_mass(total) else { .. }
            # (two_pass.rs idiom) — Some only for finite, strictly
            # positive totals, so every division below it is safe
            pooled = sf.window(line, before=_GUARD_POOL_BEFORE)
            if re.search(
                rf"let\s+Some\s*\(\s*(?:mut\s+)?{re.escape(last)}\s*\)\s*=\s*"
                rf"(?:\w+(?:::|\.))*\w*positive_\w*mass\s*\(",
                pooled,
            ):
                continue
            findings.append(
                Finding(
                    rule=self.id,
                    file=sf.path,
                    line=line,
                    message=(
                        f"unguarded division by mass-like `{chain}` — route "
                        "through choose_branch/sanitize_mass or clamp with "
                        ".max(f64::MIN_POSITIVE) / a `> 0.0 && is_finite` check "
                        "(eq. (2) q-positivity)"
                    ),
                    snippet=snippet(sf, line),
                )
            )
        return findings
