//! Miniature property-testing harness (offline replacement for `proptest`).
//!
//! A property is a closure over a [`Gen`] (a seeded random-input generator).
//! [`check`] runs it for N cases; on failure it re-raises with the failing
//! case's seed so the case can be reproduced exactly:
//!
//! ```ignore
//! // (ignore: doctest binaries miss the xla rpath in this offline image;
//! // the same property runs as a unit test below)
//! use kss::util::testing::{check, Gen};
//! check("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Deliberately tiny: no shrinking, but seeds make failures replayable, which
//! is what matters for invariant testing of the sampler tree and coordinator
//! state machines.

use super::rng::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Seed of this case, printed on failure.
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        self.rng.range(lo, hi_inclusive + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi_inclusive: i64) -> i64 {
        lo + self.rng.below((hi_inclusive - lo + 1) as u64) as i64
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// A vector of f32 in [lo, hi).
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }
}

/// Run `cases` random cases of the property. Panics (with the case seed) on
/// the first failing case. The base seed is fixed for reproducibility; set
/// `KSS_PROP_SEED` to explore a different region, or `KSS_PROP_CASES` to
/// scale the sweep up in a soak run.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base_seed: u64 = std::env::var("KSS_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE);
    let cases: usize =
        std::env::var("KSS_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(cases);
    for case in 0..cases {
        let case_seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(case_seed), case_seed };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce with KSS_PROP_SEED={base_seed} (case index {case})"
            );
        }
    }
}

/// Run one specific case seed of a property (reproduction helper).
pub fn check_seed(prop: impl Fn(&mut Gen), case_seed: u64) {
    let mut g = Gen { rng: Rng::new(case_seed), case_seed };
    prop(&mut g);
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "index {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes() {
        check("reverse twice is identity", 50, |g| {
            let n = g.usize_in(0, 32);
            let xs = g.vec_f32(n, -1.0, 1.0);
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always fails", 3, |_g| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("always fails"));
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-5);
        let r = std::panic::catch_unwind(|| assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3));
        assert!(r.is_err());
    }

    #[test]
    fn gen_ranges_inclusive() {
        check("ranges respect bounds", 200, |g| {
            let x = g.usize_in(3, 5);
            assert!((3..=5).contains(&x));
            let y = g.i64_in(-2, 2);
            assert!((-2..=2).contains(&y));
            let z = g.f32_in(0.5, 0.75);
            assert!((0.5..0.75).contains(&z));
        });
    }
}
