"""Layer 2: the models whose sampled-softmax training the paper evaluates.

Two model families, matching the paper's §4.1.1 (with the documented
substitutions of DESIGN.md §3):

* ``lm`` — a single-layer LSTM language model over a 10k-class vocabulary
  (the paper's "medium regularized LSTM" on Penn Tree Bank, scaled for a
  CPU-PJRT testbed). Every token position is a training example.
* ``recsys`` — a YouTube-style retrieval tower: user features plus the three
  previously watched videos are embedded and fed through an MLP to produce
  the query embedding ``h``; the output layer scores all videos.

Both models end in a dot product ``o = W h`` between the last hidden layer
and the class-embedding table — exactly the structure kernel based sampling
requires (§3 of the paper).

Entry points (lowered to HLO by ``aot.py``; rust executes them):

* ``encode``        (params, inputs)                  -> h (N, d)
* ``train_sampled`` (params, inputs, neg, sub, lr)    -> (params', loss, rows)
* ``train_full``    (params, inputs, lr)              -> (params', loss)
* ``eval_full``     (params, inputs)                  -> summed CE loss
* ``score_all``     (params, inputs)                  -> logits (N, n)

Conventions shared with the rust coordinator (runtime/manifest.rs):
params come first, in the manifest's order; ``lr`` is always the last input
of a train op; train ops return the new params in the same order, then the
scalar mean loss, and ``train_sampled`` additionally returns the updated
output-embedding rows of the sampled classes so the host mirror + kernel
tree can be updated without copying all of W.
"""

import jax
import jax.numpy as jnp

from .kernels.full_softmax import full_softmax_loss
from .kernels.sampled_softmax import sampled_softmax_loss

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


class ModelConfig:
    """Static configuration of one model variant (shapes are baked into HLO)."""

    def __init__(self, name, model, n_classes, d, batch, seq_len=None,
                 n_user_features=None, n_prev=3, hidden=128, abs_logits=False,
                 alpha=100.0):
        self.name = name
        self.model = model  # "lm" | "recsys"
        self.n_classes = n_classes
        self.d = d
        self.batch = batch
        self.seq_len = seq_len
        self.n_user_features = n_user_features
        self.n_prev = n_prev
        self.hidden = hidden
        self.abs_logits = abs_logits
        self.alpha = alpha  # quadratic-kernel α, recorded for the sampler

    @property
    def n_examples(self):
        """Training positions per batch (= rows of h)."""
        if self.model == "lm":
            return self.batch * self.seq_len
        return self.batch

    # ---- parameter specs --------------------------------------------------

    def param_specs(self):
        """Ordered (name, shape, init) triples; the manifest and the rust
        ParamStore replicate this order exactly."""
        n, d = self.n_classes, self.d
        if self.model == "lm":
            return [
                ("embed", (n, d), "normal:0.1"),
                ("wx", (d, 4 * d), "glorot"),
                ("wh", (d, 4 * d), "glorot"),
                ("b", (4 * d,), "zeros"),
                ("out_w", (n, d), "normal:0.1"),
            ]
        f, hdn = self.n_user_features, self.hidden
        return [
            ("item_emb", (n, d), "normal:0.1"),
            ("w1", (f + d, hdn), "glorot"),
            ("b1", (hdn,), "zeros"),
            ("w2", (hdn, d), "glorot"),
            ("b2", (d,), "zeros"),
            ("out_w", (n, d), "normal:0.1"),
        ]

    def data_specs(self, op, m=None):
        """Ordered (name, dtype, shape) of the non-param inputs of ``op``."""
        B = self.batch
        N = self.n_examples
        if self.model == "lm":
            T = self.seq_len
            base = [("tokens", "i32", (B, T))]
            pos = [("targets", "i32", (B, T))]
        else:
            base = [
                ("user", "f32", (B, self.n_user_features)),
                ("prev", "i32", (B, self.n_prev)),
            ]
            pos = [("pos", "i32", (B,))]
        if op == "encode" or op == "score_all":
            return base
        if op == "eval_full":
            return base + pos
        if op == "train_full":
            return base + pos + [("lr", "f32", ())]
        if op == "train_sampled":
            assert m is not None
            return base + pos + [
                ("neg", "i32", (N, m)),
                ("sub", "f32", (N, m + 1)),
                ("lr", "f32", ()),
            ]
        raise ValueError(f"unknown op {op}")

    def output_specs(self, op, m=None):
        """Ordered (name, dtype, shape) of the outputs of ``op``."""
        N, n, d = self.n_examples, self.n_classes, self.d
        params = [(name, "f32", shape) for name, shape, _ in self.param_specs()]
        if op == "encode":
            return [("h", "f32", (N, d))]
        if op == "score_all":
            return [("logits", "f32", (N, n))]
        if op == "eval_full":
            return [("sum_loss", "f32", ())]
        if op == "train_full":
            return params + [("loss", "f32", ())]
        if op == "train_sampled":
            return params + [("loss", "f32", ()), ("rows", "f32", (N, m + 1, d))]
        raise ValueError(f"unknown op {op}")

    def init_params(self, key):
        """Reference initializer (tests + parity with the rust ParamStore)."""
        params = []
        for name, shape, init in self.param_specs():
            key, sub = jax.random.split(key)
            if init == "zeros":
                params.append(jnp.zeros(shape, jnp.float32))
            elif init.startswith("normal:"):
                std = float(init.split(":")[1])
                params.append(std * jax.random.normal(sub, shape, jnp.float32))
            elif init == "glorot":
                fan_in, fan_out = shape[0], shape[-1]
                std = (2.0 / (fan_in + fan_out)) ** 0.5
                params.append(std * jax.random.normal(sub, shape, jnp.float32))
            else:
                raise ValueError(init)
        return params


# ---------------------------------------------------------------------------
# encoders (h = last hidden layer)
# ---------------------------------------------------------------------------


def _lstm_encode(cfg, params, tokens):
    """Single-layer LSTM over (B, T) tokens -> h for every position (B*T, d).

    Position t's query embedding is the LSTM state *after* consuming token t;
    the training target at that position is token t+1 (the batcher shifts)."""
    embed, wx, wh, b, _ = params
    d = cfg.d
    x = embed[tokens]  # (B, T, d)
    x = jnp.swapaxes(x, 0, 1)  # (T, B, d): scan over time

    def cell(carry, xt):
        hprev, cprev = carry
        z = xt @ wx + hprev @ wh + b  # (B, 4d)
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * cprev + jax.nn.sigmoid(i) * jnp.tanh(g)
        hnew = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (hnew, c), hnew

    B = tokens.shape[0]
    h0 = jnp.zeros((B, d), jnp.float32)
    _, hs = jax.lax.scan(cell, (h0, h0), x)  # (T, B, d)
    return jnp.swapaxes(hs, 0, 1).reshape(-1, d)  # (B*T, d)


def _recsys_encode(cfg, params, user, prev):
    """MLP tower over user features + mean embedding of the previously
    watched videos (Covington et al.-style) -> h (B, d)."""
    item_emb, w1, b1, w2, b2, _ = params
    prev_emb = jnp.mean(item_emb[prev], axis=1)  # (B, d)
    x = jnp.concatenate([user, prev_emb], axis=-1)
    hdn = jnp.tanh(x @ w1 + b1)
    return hdn @ w2 + b2


def encode(cfg, params, *data):
    if cfg.model == "lm":
        return _lstm_encode(cfg, params, *data)
    return _recsys_encode(cfg, params, *data)


def _positives(pos_input):
    """Flatten the positive-class input to (N,)."""
    return pos_input.reshape(-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def score_all(cfg, params, *data):
    """Raw logits o = h W^T over all classes, (N, n). The exact-softmax and
    flat-kernel samplers consume these host-side (abs applied there when the
    model is an absolute-softmax variant)."""
    h = encode(cfg, params, *data)
    out_w = params[-1]
    return h @ out_w.T


def eval_full(cfg, params, *data_and_pos):
    """Summed full-softmax CE over the batch (rust divides by count)."""
    *data, pos = data_and_pos
    h = encode(cfg, params, *data)
    loss = full_softmax_loss(h, params[-1], _positives(pos), cfg.abs_logits)
    return jnp.sum(loss)


def train_sampled(cfg, params, *args):
    """One SGD step of sampled softmax. Returns (params', loss, rows) where
    ``rows = out_w'[s]`` are the post-update embeddings of the sampled
    classes (positive at column 0) for the host mirror + kernel tree."""
    *data_and_pos, neg, sub, lr = args
    *data, pos = data_and_pos
    s = jnp.concatenate([_positives(pos)[:, None], neg], axis=1)  # (N, S)

    def objective(ps):
        h = encode(cfg, ps, *data)
        ws = ps[-1][s]  # (N, S, d)
        return jnp.mean(sampled_softmax_loss(h, ws, sub, cfg.abs_logits))

    loss, grads = jax.value_and_grad(objective)(params)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    rows = new_params[-1][s]
    return (*new_params, loss, rows)


def train_full(cfg, params, *args):
    """One SGD step of the full-softmax baseline."""
    *data_and_pos, lr = args
    *data, pos = data_and_pos

    def objective(ps):
        h = encode(cfg, ps, *data)
        return jnp.mean(full_softmax_loss(h, ps[-1], _positives(pos), cfg.abs_logits))

    loss, grads = jax.value_and_grad(objective)(params)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return (*new_params, loss)


# ---------------------------------------------------------------------------
# flat-signature wrappers + AOT lowering
# ---------------------------------------------------------------------------


def entry_fn(cfg, op, m=None):
    """A flat-argument function (params..., data..., [lr]) -> tuple, ready to
    be jitted/lowered. Tuple-ness matters: rust unpacks with to_tuple."""
    n_params = len(cfg.param_specs())

    def fn(*args):
        params = list(args[:n_params])
        rest = args[n_params:]
        if op == "encode":
            return (encode(cfg, params, *rest),)
        if op == "score_all":
            return (score_all(cfg, params, *rest),)
        if op == "eval_full":
            return (eval_full(cfg, params, *rest),)
        if op == "train_full":
            return train_full(cfg, params, *rest)
        if op == "train_sampled":
            return train_sampled(cfg, params, *rest)
        raise ValueError(op)

    fn.__name__ = f"{cfg.name}_{op}" + (f"_m{m}" if m else "")
    return fn


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def example_args(cfg, op, m=None):
    """ShapeDtypeStructs for lowering ``entry_fn(cfg, op, m)``."""
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape, _ in cfg.param_specs()]
    for _, dtype, shape in cfg.data_specs(op, m):
        specs.append(jax.ShapeDtypeStruct(shape, _DTYPES[dtype]))
    return specs


def lower_to_hlo_text(cfg, op, m=None):
    """Lower one entry point to HLO text — the xla_extension-0.5.1-safe
    interchange format (DESIGN.md §2): jax >= 0.5 serialized protos carry
    64-bit instruction ids the runtime rejects; the text parser re-ids."""
    from jax._src.lib import xla_client as xc

    fn = entry_fn(cfg, op, m)
    # keep_unused: the runtime feeds *all* params to every op (encode does
    # not read out_w, for instance) — argument arity must stay stable.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args(cfg, op, m))
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
