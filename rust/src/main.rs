//! `kss` — launcher for the kernel-sampled-softmax system.
//!
//! Subcommands:
//!
//! * `kss info` — list the models/artifacts in the manifest.
//! * `kss train` — one training run (model × sampler × m), metrics to JSONL.
//! * `kss experiment` — a (samplers × m) grid, the engine behind the paper's
//!   figures; writes per-run JSONL + summary.json and prints the Figure-2
//!   style bias table.
//! * `kss demo` — 30-second tiny-model walkthrough of the whole stack.
//! * `kss serve` — closed-loop load test of the online serving subsystem
//!   (sharded snapshots + micro-batcher + top-k retrieval); pure L3, needs
//!   no artifacts. Exits non-zero when the deadline-miss rate exceeds
//!   `--miss-threshold` — the CI smoke gate.
//!
//! Artifacts must exist for train/experiment/demo (`make artifacts`).
//! Logging level: `KSS_LOG`.

#![deny(unsafe_op_in_unsafe_fn)]

use anyhow::Result;
use kss::coordinator::{run_grid, GridSpec, MetricsSink, TrainConfig, Trainer};
use kss::runtime::Engine;
use kss::serve::{BatcherConfig, LoadGenConfig, TopKConfig};
use kss::util::cli::{Args, OptSpec};
use kss::{error, info};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn main() {
    kss::util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            error!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "artifacts", help: "artifacts directory", default: Some("artifacts".into()) },
        OptSpec { name: "model", help: "manifest model name", default: Some("tiny".into()) },
        OptSpec { name: "sampler", help: "sampler name or 'full'", default: Some("quadratic".into()) },
        OptSpec { name: "samplers", help: "comma list (experiment)", default: None },
        OptSpec { name: "m", help: "sample size(s), comma list", default: Some("8".into()) },
        OptSpec { name: "lr", help: "SGD learning rate (0 = model default)", default: Some("0".into()) },
        OptSpec { name: "epochs", help: "training epochs", default: Some("1".into()) },
        OptSpec { name: "train-size", help: "train tokens/events", default: Some("8000".into()) },
        OptSpec { name: "valid-size", help: "validation tokens/events", default: Some("1000".into()) },
        OptSpec { name: "max-steps", help: "cap steps per epoch (0 = all)", default: Some("0".into()) },
        OptSpec { name: "eval-every", help: "eval every k steps (0 = per epoch)", default: Some("0".into()) },
        OptSpec { name: "eval-batches", help: "eval batch cap (0 = all)", default: Some("20".into()) },
        OptSpec { name: "threads", help: "sampling threads (0 = auto)", default: Some("0".into()) },
        OptSpec { name: "pipeline-depth", help: "1 = sequential, 2 = overlap sample with step", default: Some("1".into()) },
        OptSpec { name: "sample-mode", help: "per-row | two-pass (batch-shared pool) | midx (inverted multi-index; kernel-tree samplers only)", default: Some("per-row".into()) },
        OptSpec { name: "pool-factor", help: "two-pass pool divisor α (P = B·m/α)", default: Some("4".into()) },
        OptSpec { name: "seed", help: "master seed", default: Some("42".into()) },
        OptSpec { name: "out", help: "metrics output directory", default: Some("runs".into()) },
        OptSpec { name: "full", help: "include full-softmax reference (experiment)", default: Some("true".into()) },
    ]
}

fn parse_config(args: &Args) -> Result<TrainConfig> {
    // --sample-mode two-pass rewrites the base kernel-tree sampler names
    // to their registered *-2pass forms (one registry name per drawing
    // engine, so run ids / logs / metrics stay self-describing)
    let sampler = {
        let name = args.get_string_or("sampler", "quadratic");
        match args.get_string_or("sample-mode", "per-row").as_str() {
            "per-row" => name,
            "two-pass" => match name.as_str() {
                "quadratic" | "rff" => format!("{name}-2pass"),
                already if already.ends_with("-2pass") => name,
                other => anyhow::bail!(
                    "--sample-mode two-pass needs an unsharded kernel-tree sampler \
                     (quadratic or rff), got '{other}'"
                ),
            },
            "midx" => match name.as_str() {
                "quadratic" | "rff" => format!("{name}-midx"),
                already if already.ends_with("-midx") => name,
                other => anyhow::bail!(
                    "--sample-mode midx needs an unsharded kernel-tree sampler \
                     (quadratic or rff), got '{other}'"
                ),
            },
            other => {
                anyhow::bail!("unknown --sample-mode '{other}' (known: per-row, two-pass, midx)")
            }
        }
    };
    Ok(TrainConfig {
        model: args.get_string_or("model", "tiny"),
        sampler,
        m: args.get_usize_list("m", &[8])?[0],
        lr: args.get_f64("lr", 0.0)? as f32,
        epochs: args.get_usize("epochs", 1)?,
        train_size: args.get_usize("train-size", 8_000)?,
        valid_size: args.get_usize("valid-size", 1_000)?,
        max_steps_per_epoch: args.get_usize("max-steps", 0)?,
        eval_every: args.get_usize("eval-every", 0)?,
        eval_batches: args.get_usize("eval-batches", 20)?,
        threads: args.get_usize("threads", 0)?,
        seed: args.get_u64("seed", 42)?,
        pipeline_depth: args.get_usize("pipeline-depth", 1)?,
        pool_factor: args.get_f64("pool-factor", 4.0)?,
        ..Default::default()
    })
}

fn serve_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "classes", help: "catalog size (classes)", default: Some("10000".into()) },
        OptSpec { name: "d", help: "embedding dimension", default: Some("16".into()) },
        OptSpec { name: "kernel", help: "kernel family (quadratic|rff)", default: Some("quadratic".into()) },
        OptSpec { name: "alpha", help: "quadratic kernel α", default: Some("100".into()) },
        OptSpec { name: "rff-dim", help: "rff feature dim D (0 = 4d)", default: Some("0".into()) },
        OptSpec { name: "shards", help: "shard count", default: Some("4".into()) },
        OptSpec { name: "workers", help: "serve worker threads", default: Some("2".into()) },
        OptSpec { name: "clients", help: "closed-loop client threads", default: Some("4".into()) },
        OptSpec { name: "requests", help: "requests per client", default: Some("1000".into()) },
        OptSpec { name: "m", help: "negatives per request", default: Some("8".into()) },
        OptSpec { name: "topk", help: "retrieval k (every 16th req)", default: Some("10".into()) },
        OptSpec { name: "beam", help: "retrieval beam width", default: Some("8".into()) },
        OptSpec { name: "max-batch", help: "micro-batch size cap", default: Some("32".into()) },
        OptSpec { name: "max-wait-us", help: "batch deadline (us)", default: Some("2000".into()) },
        OptSpec { name: "queue-cap", help: "bounded queue capacity", default: Some("4096".into()) },
        OptSpec { name: "updates", help: "classes per publish (0=off)", default: Some("32".into()) },
        OptSpec { name: "midx-clusters", help: "route draws through the inverted multi-index with K clusters (0=off; needs --shards 1)", default: Some("0".into()) },
        OptSpec { name: "deadline-ms", help: "end-to-end budget (ms)", default: Some("20".into()) },
        OptSpec { name: "miss-threshold", help: "max miss rate", default: Some("0.05".into()) },
        OptSpec { name: "seed", help: "master seed", default: Some("42".into()) },
        OptSpec {
            name: "metrics-path",
            help: "write Prometheus exposition here on exit",
            default: Some("".into()),
        },
        OptSpec {
            name: "scenario",
            help: "load (sharded index) | churn (streaming vocabulary)",
            default: Some("load".into()),
        },
        OptSpec { name: "insert-every", help: "churn: insert 1 class every k rounds (0=off)", default: Some("1".into()) },
        OptSpec { name: "retire-every", help: "churn: retire 1 class every k rounds (0=off)", default: Some("2".into()) },
        OptSpec { name: "update-batch", help: "churn: classes re-embedded per round", default: Some("16".into()) },
        OptSpec { name: "memtable-cap", help: "churn: fold memtable at this size", default: Some("256".into()) },
        OptSpec { name: "tombstone-frac", help: "churn: fold when tombstones exceed this arena fraction", default: Some("0.25".into()) },
    ]
}

fn run(argv: Vec<String>) -> Result<()> {
    let (cmd, rest) = match argv.split_first() {
        Some((c, rest)) if !c.starts_with("--") => (c.clone(), rest.to_vec()),
        _ => ("help".to_string(), argv),
    };
    // `serve` is pure L3 with its own flag set; everything else shares the
    // training specs
    if cmd == "serve" {
        let args = Args::parse("kss serve", &rest, &serve_specs(), &["help"])?;
        if args.wants_help() {
            println!("{}", args.usage());
            return Ok(());
        }
        return serve_cmd(&args);
    }
    let args = Args::parse("kss <info|train|experiment|demo|serve>", &rest, &specs(), &["help"])?;
    if args.wants_help() || cmd == "help" {
        println!("{}", args.usage());
        println!("subcommands: info, train, experiment, demo, serve (own flags: kss serve --help)");
        // one registry drives --sampler validation and this help text —
        // new kernels appear here automatically
        println!("samplers (--sampler/--samplers):");
        for info in kss::sampler::SAMPLER_REGISTRY {
            println!("  {:<18} {}", info.name, info.summary);
        }
        return Ok(());
    }
    let artifacts = PathBuf::from(args.get_string_or("artifacts", "artifacts"));
    match cmd.as_str() {
        "info" => info_cmd(&artifacts),
        "train" => train_cmd(&artifacts, &args),
        "experiment" => experiment_cmd(&artifacts, &args),
        "demo" => demo_cmd(&artifacts),
        other => {
            anyhow::bail!("unknown subcommand '{other}' (info, train, experiment, demo, serve)")
        }
    }
}

fn serve_cmd(args: &Args) -> Result<()> {
    match args.get_string_or("scenario", "load").as_str() {
        "load" => {}
        "churn" => return churn_cmd(args),
        other => anyhow::bail!("unknown --scenario '{other}' (known: load, churn)"),
    }
    let cfg = LoadGenConfig {
        n_classes: args.get_usize("classes", 10_000)?,
        d: args.get_usize("d", 16)?,
        kernel: kss::serve::ServeKernel::parse(&args.get_string_or("kernel", "quadratic"))?,
        alpha: args.get_f64("alpha", 100.0)?,
        rff_dim: args.get_usize("rff-dim", 0)?,
        shards: args.get_usize("shards", 4)?,
        workers: args.get_usize("workers", 2)?,
        clients: args.get_usize("clients", 4)?,
        requests: args.get_usize("requests", 1_000)?,
        m: args.get_usize("m", 8)?,
        topk: TopKConfig {
            k: args.get_usize("topk", 10)?,
            beam_width: args.get_usize("beam", 8)?,
        },
        batcher: BatcherConfig {
            max_batch: args.get_usize("max-batch", 32)?,
            max_wait: Duration::from_micros(args.get_u64("max-wait-us", 2_000)?),
            queue_cap: args.get_usize("queue-cap", 4_096)?,
        },
        updates_per_publish: args.get_usize("updates", 32)?,
        deadline: Duration::from_millis(args.get_u64("deadline-ms", 20)?),
        seed: args.get_u64("seed", 42)?,
        metrics_path: {
            let p = args.get_string_or("metrics-path", "");
            if p.is_empty() { None } else { Some(PathBuf::from(p)) }
        },
        midx_clusters: args.get_usize("midx-clusters", 0)?,
    };
    anyhow::ensure!(
        cfg.midx_clusters == 0 || cfg.shards == 1,
        "--midx-clusters needs --shards 1 (the coarse CDF spans the whole class range)"
    );
    let miss_threshold = args.get_f64("miss-threshold", 0.05)?;
    info!(
        "serve load test: {} classes × d={} ({:?} kernel) in {} shards, \
         {} workers, {} clients × {} requests",
        cfg.n_classes, cfg.d, cfg.kernel, cfg.shards, cfg.workers, cfg.clients, cfg.requests
    );
    let report = kss::serve::run_load_test(&cfg);
    println!("serve load test ({:.2}s wall):", report.wall_s);
    println!("  completed        {:>10}  ({:.0} req/s)", report.completed, report.throughput_rps);
    println!("  topk calls       {:>10}", report.topk_calls);
    println!("  rejected         {:>10}  (bounded queue shed)", report.rejected);
    println!(
        "  latency          p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  max {:.3} ms",
        report.latency_p50_s * 1e3,
        report.latency_p95_s * 1e3,
        report.latency_p99_s * 1e3,
        report.latency_max_s * 1e3
    );
    println!(
        "  deadline misses  {:>9.3}%  (budget {:.1} ms, threshold {:.1}%)",
        report.deadline_miss_rate * 100.0,
        cfg.deadline.as_secs_f64() * 1e3,
        miss_threshold * 100.0
    );
    println!(
        "  publishes        {:>10}  (reclaimed {}, copied {}, replayed {} batches)",
        report.publishes,
        report.publish_stats.reclaimed,
        report.publish_stats.copied,
        report.publish_stats.replayed_batches
    );
    println!(
        "  publish cost     build p95 {:.3} ms, swap max {:.6} ms (readers wait only for the swap)",
        report.publish_build_p95_s * 1e3,
        report.publish_swap_max_s * 1e3
    );
    match &cfg.metrics_path {
        Some(p) => println!("  metrics          written to {}", p.display()),
        // no path given: still surface the exposition so an interactive
        // run (and the CI log) sees every series without another flag
        None => println!("--- metrics exposition ---\n{}", report.metrics_text),
    }
    anyhow::ensure!(
        report.completed > 0,
        "no requests completed — the serving stack is wedged"
    );
    anyhow::ensure!(
        report.deadline_miss_rate <= miss_threshold,
        "deadline-miss rate {:.3}% exceeds threshold {:.3}%",
        report.deadline_miss_rate * 100.0,
        miss_threshold * 100.0
    );
    Ok(())
}

/// `kss serve --scenario churn`: the streaming-vocabulary closed loop —
/// readers sample composite snapshots (every draw asserted q-positive and
/// live in its own generation; violations panic inside the run) while the
/// writer inserts/retires/re-embeds classes. Exits non-zero when the
/// deadline-miss rate exceeds `--miss-threshold`.
fn churn_cmd(args: &Args) -> Result<()> {
    let cfg = kss::serve::ChurnConfig {
        n_classes: args.get_usize("classes", 10_000)?,
        d: args.get_usize("d", 16)?,
        kernel: kss::serve::ServeKernel::parse(&args.get_string_or("kernel", "quadratic"))?,
        alpha: args.get_f64("alpha", 100.0)?,
        rff_dim: args.get_usize("rff-dim", 0)?,
        clients: args.get_usize("clients", 4)?,
        draws: args.get_usize("requests", 1_000)?,
        m: args.get_usize("m", 8)?,
        insert_every: args.get_usize("insert-every", 1)?,
        retire_every: args.get_usize("retire-every", 2)?,
        update_batch: args.get_usize("update-batch", 16)?,
        policy: kss::vocab::CompactionPolicy {
            memtable_cap: args.get_usize("memtable-cap", 256)?,
            max_tombstone_frac: args.get_f64("tombstone-frac", 0.25)?,
        },
        deadline: Duration::from_millis(args.get_u64("deadline-ms", 20)?),
        seed: args.get_u64("seed", 42)?,
        metrics_path: {
            let p = args.get_string_or("metrics-path", "");
            if p.is_empty() { None } else { Some(PathBuf::from(p)) }
        },
    };
    let miss_threshold = args.get_f64("miss-threshold", 0.05)?;
    info!(
        "serve churn test: {} classes × d={} ({:?} kernel), {} clients × {} draws, \
         insert every {}, retire every {}, memtable cap {}",
        cfg.n_classes,
        cfg.d,
        cfg.kernel,
        cfg.clients,
        cfg.draws,
        cfg.insert_every,
        cfg.retire_every,
        cfg.policy.memtable_cap
    );
    let report = kss::serve::run_churn_test(&cfg);
    println!("serve churn test ({:.2}s wall):", report.wall_s);
    println!("  draws            {:>10}  ({:.0} req/s)", report.draws, report.throughput_rps);
    println!(
        "  latency          p50 {:.3} ms  p95 {:.3} ms  max {:.3} ms",
        report.latency_p50_s * 1e3,
        report.latency_p95_s * 1e3,
        report.latency_max_s * 1e3
    );
    println!(
        "  deadline misses  {:>9.3}%  (budget {:.1} ms, threshold {:.1}%)",
        report.deadline_miss_rate * 100.0,
        cfg.deadline.as_secs_f64() * 1e3,
        miss_threshold * 100.0
    );
    println!(
        "  churn            {} inserted, {} retired, {} compactions, {} live at exit",
        report.inserts, report.retires, report.compactions, report.live_classes
    );
    println!(
        "  tier routing     arena {} / memtable {} negatives",
        report.tier_arena, report.tier_memtable
    );
    match &cfg.metrics_path {
        Some(p) => println!("  metrics          written to {}", p.display()),
        None => println!("--- metrics exposition ---\n{}", report.metrics_text),
    }
    anyhow::ensure!(report.draws > 0, "no draws completed — the churn loop is wedged");
    anyhow::ensure!(
        report.inserts > 0 || cfg.insert_every == 0,
        "writer never inserted a class"
    );
    anyhow::ensure!(
        report.deadline_miss_rate <= miss_threshold,
        "deadline-miss rate {:.3}% exceeds threshold {:.3}%",
        report.deadline_miss_rate * 100.0,
        miss_threshold * 100.0
    );
    Ok(())
}

fn info_cmd(artifacts: &Path) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    println!("platform: {}", engine.platform());
    println!(
        "{:<12} {:>8} {:>5} {:>6} {:>5} {:>8}  m values",
        "model", "classes", "d", "batch", "abs", "kind"
    );
    for (name, spec) in &engine.manifest().models {
        println!(
            "{:<12} {:>8} {:>5} {:>6} {:>5} {:>8}  {:?}",
            name,
            spec.n_classes,
            spec.d,
            spec.batch,
            spec.abs_logits,
            format!("{:?}", spec.kind).to_lowercase(),
            spec.available_m()
        );
    }
    Ok(())
}

fn train_cmd(artifacts: &Path, args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    let cfg = parse_config(args)?;
    let out = PathBuf::from(args.get_string_or("out", "runs"));
    let run_id = cfg.run_id();
    info!("training {run_id}");
    let mut sink = MetricsSink::to_dir(&out, &run_id)?;
    let mut trainer = Trainer::new(&engine, cfg)?;
    let res = trainer.train(&mut sink)?;
    println!("run {run_id}");
    println!("  final eval loss {:.4} (ppl {:.2})", res.final_loss, res.final_loss.exp());
    println!("  best  eval loss {:.4}", res.best_loss);
    println!("  steps {}", res.steps);
    println!(
        "phase breakdown (share of accounted wall):\n{}",
        trainer.phases.report_with_throughput(res.steps)
    );
    Ok(())
}

fn experiment_cmd(artifacts: &Path, args: &Args) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    let base = parse_config(args)?;
    let samplers = match args.get_str("samplers") {
        Some(_) => args.get_str_list("samplers", &[]),
        None => vec![base.sampler.clone()],
    };
    let ms = args.get_usize_list("m", &[8])?;
    let include_full = args.get_bool("full", true)?;
    let out = PathBuf::from(args.get_string_or("out", "runs"));
    let grid = GridSpec { base, samplers, ms: ms.clone(), include_full };
    let summaries = run_grid(&engine, &grid, Some(&out))?;
    println!("\nfinal full-softmax eval loss (bias table, Figure-2 style):");
    print!("{}", kss::coordinator::experiment::bias_table(&summaries, &ms));
    Ok(())
}

fn demo_cmd(artifacts: &Path) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    println!("kernel-sampled-softmax demo (tiny model, ~30s)\n");
    let grid = GridSpec {
        base: TrainConfig {
            model: "tiny".into(),
            epochs: 2,
            train_size: 640,
            valid_size: 160,
            eval_batches: 5,
            ..Default::default()
        },
        samplers: vec!["uniform".into(), "quadratic".into(), "softmax".into()],
        ms: vec![8],
        include_full: true,
    };
    let summaries = run_grid(&engine, &grid, None)?;
    println!("\nfinal eval loss after 2 epochs (m = 8 of 128 classes):");
    for s in &summaries {
        println!("  {:<16} {:.4}", s.label(), s.final_loss);
    }
    println!("\nExpected shape (paper Fig. 2): softmax ≈ full < quadratic << uniform.");
    Ok(())
}
