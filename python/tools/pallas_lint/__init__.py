"""pallas-lint: static invariant analysis for the kss Rust sources.

The build container has no rust toolchain, so clippy/miri can never gate a
PR here. This package is the no-toolchain stand-in: a Rust tokenizer and
lightweight parser (`frontend`) shared by a set of repo-specific rules
(`rules/`) that enforce the correctness contracts the paper's eq. (2)
exactness rests on — the ops accumulation-order contract, the zero-mass
q-positivity guards, panic-free serve/pipeline workers, lock-acquisition
ordering, unsafe-block audits, and sampler-registry consistency.

Run the full pass:

    PYTHONPATH=python/tools python3 -m pallas_lint --root . --report ANALYSIS.json

Pre-existing, justified findings live in `baseline.json` (the waiver
file); the pass fails only on findings not covered by a waiver, so new
violations block CI while the waived remainder is documented in place.
"""

__version__ = "1.0.0"
